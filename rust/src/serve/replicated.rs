//! [`Backend`] #2: one worker thread per programmed die, router-dispatched.
//!
//! This is PR-1's synchronous `Fleet::serve` loop lifted onto real
//! concurrency: every [`Chip`] lives on its own worker thread pulling
//! requests from a per-chip queue, [`Router`] picks the die at submit
//! time, and the [`HealthMonitor`] runs *live* — every `reweigh_every`
//! completions it refreshes the router's traffic weights
//! ([`HealthMonitor::traffic_weights`]), flags drifting dies for in-place
//! recalibration (the worker recalibrates between requests, on its own
//! thread), and evicts dies under the accuracy floor.  Labeled probe
//! requests ([`InferRequest::with_label`]) are what feed accuracy-based
//! drift detection; unlabeled traffic still drives latency/abstention
//! reweighting.
//!
//! Each worker applies the early stopper per request (Wilson interval on
//! the top-two votes, like the coordinator's scheduler).  The request's
//! trial indices derive from `(backend seed, request id)` only, but the
//! comparator-noise stream at those indices is the *serving die's* — each
//! chip keeps the private RNG identity PR-1 gave it — so a response is
//! reproducible for a fixed fleet (same fleet seed, chip count, routing),
//! not across fleets of different shapes.  For shape-independent votes
//! use the pipelined backend, whose dies share one logical stream.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::{Metrics, MetricsSnapshot};
use crate::dataset::Dataset;
use crate::engine::TrialEngine;
use crate::fleet::{
    Calibrator, Chip, ChipId, ChipStats, Fleet, FleetSnapshot, HealthMonitor, Router,
};
use crate::neuron::WtaOutcome;
use crate::stats::ci::lead_is_decided;
use crate::telemetry::{journal::DEFAULT_CAPACITY, EventKind, Journal, MetricsTree};

use super::probe::ProbeInjector;
use super::{trial_stream_base, Backend, InferRequest, InferResponse};

/// Knobs of the replicated backend.
#[derive(Debug, Clone)]
pub struct ReplicatedOptions {
    /// Base seed of per-request trial streams.
    pub seed: u64,
    /// Minimum trials before the early stopper may fire.
    pub min_trials: u32,
    /// Refresh traffic weights / drift flags every this many completions.
    pub reweigh_every: u64,
    /// Labeled health probes injected per caller request, in [0, 1]
    /// (0 disables).  Probes draw from the calibration set handed to
    /// [`ReplicatedFleetBackend::start`], so accuracy steering works on
    /// unlabeled traffic; they are excluded from the request metrics but
    /// their trials count as executed (real engine work).
    pub probe_rate: f64,
    /// Fleet-wide id of this group's first die: telemetry labels read
    /// `die#<label_base + local idx>` so a `2x(3x(die))` tree names all
    /// six dies distinctly.  Chips still use local indices internally.
    pub label_base: usize,
    /// Shared event journal of the deployment tree; `None` spawns a
    /// private ring so health events are never silently dropped.
    pub journal: Option<Arc<Journal>>,
}

impl Default for ReplicatedOptions {
    fn default() -> Self {
        Self {
            seed: 0x5E12E,
            min_trials: 5,
            reweigh_every: 32,
            probe_rate: 0.0,
            label_base: 0,
            journal: None,
        }
    }
}

struct Job {
    req: InferRequest,
    reply: mpsc::Sender<InferResponse>,
    submitted: Instant,
    /// Injected health probe: feeds the monitor, skips request metrics.
    probe: bool,
}

/// State shared between the submit path and every worker.
struct Shared {
    health: Mutex<HealthMonitor>,
    /// Router traffic weights (health-driven, refreshed live).
    weights: Mutex<Vec<f64>>,
    /// In-flight requests per chip.
    loads: Vec<AtomicU64>,
    /// Per-chip "recalibrate before your next request" flags.
    recal: Vec<AtomicBool>,
    stats: Mutex<Vec<ChipStats>>,
    completed: AtomicU64,
}

/// Replicated-fleet serving session.
pub struct ReplicatedFleetBackend {
    txs: Vec<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    router: Router,
    probes: Option<ProbeInjector>,
    shared: Arc<Shared>,
    metrics: Arc<Metrics>,
    journal: Arc<Journal>,
    label_base: usize,
}

impl ReplicatedFleetBackend {
    /// Take ownership of a programmed (and ideally calibrated) fleet and
    /// spawn one worker thread per die.  `cal` supplies the held-out set
    /// + calibrator that drifting dies recalibrate against live; without
    /// it, drift flags are still raised but recalibration is skipped.
    ///
    /// Crate-private: deployments are built by [`crate::serve::plan`]
    /// (external callers with a hand-programmed fleet go through
    /// [`crate::serve::plan::lift_fleet`]).
    pub(crate) fn start<E: TrialEngine + 'static>(
        fleet: Fleet<E>,
        cal: Option<(Dataset, Calibrator)>,
        mut opts: ReplicatedOptions,
    ) -> Self {
        let Fleet { chips, router, mut health, .. } = fleet;
        let n = chips.len();
        let journal =
            opts.journal.clone().unwrap_or_else(|| Journal::new(DEFAULT_CAPACITY));
        let labels: Vec<String> =
            (0..n).map(|i| format!("die#{}", opts.label_base + i)).collect();
        health.attach_journal(journal.clone(), labels);
        opts.journal = Some(journal.clone()); // workers log through the same ring
        let initial_weights = health.traffic_weights();
        let shared = Arc::new(Shared {
            health: Mutex::new(health),
            weights: Mutex::new(initial_weights),
            loads: (0..n).map(|_| AtomicU64::new(0)).collect(),
            recal: (0..n).map(|_| AtomicBool::new(false)).collect(),
            stats: Mutex::new(vec![ChipStats::default(); n]),
            completed: AtomicU64::new(0),
        });
        let metrics = Metrics::new();
        // Probes draw from the same held-out set the calibrator uses — the
        // slice callers never see, so probe accuracy is honest.
        let probes = cal
            .as_ref()
            .and_then(|(ds, _)| ProbeInjector::new(ds.clone(), opts.probe_rate));
        let cal = cal.map(Arc::new);
        let mut txs = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        for (idx, chip) in chips.into_iter().enumerate() {
            debug_assert_eq!(chip.id, idx, "chips must arrive in id order");
            let (tx, rx) = mpsc::channel::<Job>();
            txs.push(tx);
            let shared = shared.clone();
            let metrics = metrics.clone();
            let cal = cal.clone();
            let opts = opts.clone();
            let worker = std::thread::Builder::new()
                .name(format!("raca-chip-{idx}"))
                .spawn(move || worker_loop(chip, rx, shared, metrics, cal, opts))
                .expect("spawning fleet worker thread");
            workers.push(worker);
        }
        let label_base = opts.label_base;
        Self { txs, workers, router, probes, shared, metrics, journal, label_base }
    }

    pub fn num_chips(&self) -> usize {
        self.txs.len()
    }

    /// Health probes injected so far ([`ReplicatedOptions::probe_rate`]).
    pub fn probes_sent(&self) -> u64 {
        self.probes.as_ref().map(|p| p.sent()).unwrap_or(0)
    }

    /// Route one job (caller request or probe) onto a healthy worker.
    fn enqueue(
        &self,
        req: InferRequest,
        reply: mpsc::Sender<InferResponse>,
        probe: bool,
    ) -> Result<()> {
        let healthy = self.shared.health.lock().unwrap().healthy();
        let loads: Vec<u64> = self.shared.loads.iter().map(|l| l.load(Relaxed)).collect();
        let weights = self.shared.weights.lock().unwrap().clone();
        let chip = self
            .router
            .pick(&healthy, &loads, &weights)
            .ok_or_else(|| anyhow!("no healthy chips left in the fleet"))?;
        if !probe {
            self.metrics.requests_admitted.fetch_add(1, Relaxed);
            self.journal.record(
                EventKind::RequestAdmitted,
                &format!("die#{}", self.label_base + chip),
                format!("id {}", req.id),
            );
        }
        self.shared.loads[chip].fetch_add(1, Relaxed);
        if self.txs[chip]
            .send(Job { req, reply, submitted: Instant::now(), probe })
            .is_err()
        {
            self.shared.loads[chip].fetch_sub(1, Relaxed);
            return Err(anyhow!("fleet worker {chip} is gone"));
        }
        Ok(())
    }

    /// Ids still eligible for routing.
    pub fn healthy(&self) -> Vec<ChipId> {
        self.shared.health.lock().unwrap().healthy()
    }

    /// Current health-driven router weights.
    pub fn traffic_weights(&self) -> Vec<f64> {
        self.shared.weights.lock().unwrap().clone()
    }

    /// Point-in-time per-chip serving stats.
    pub fn snapshot(&self) -> FleetSnapshot {
        FleetSnapshot {
            chips: self
                .shared
                .stats
                .lock()
                .unwrap()
                .iter()
                .enumerate()
                .map(|(id, s)| (id, s.clone()))
                .collect(),
        }
    }
}

impl Backend for ReplicatedFleetBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        let budget = req.max_trials;
        self.enqueue(req, reply, false)?;
        // Piggyback a labeled probe on live traffic when one is due: the
        // worker records its health sample like any labeled request; the
        // response goes nowhere (the receiver is dropped right here).
        if let Some(probes) = &self.probes {
            if let Some(probe) = probes.next(budget) {
                let (tx, _rx) = mpsc::channel();
                if let Err(e) = self.enqueue(probe, tx, true) {
                    log::warn!("probe injection failed: {e:#}");
                }
            }
        }
        Ok(())
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_tree(&self) -> MetricsTree {
        let stats = self.shared.stats.lock().unwrap().clone();
        let weights = self.shared.weights.lock().unwrap().clone();
        let health = self.shared.health.lock().unwrap();
        let children = stats
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let h = health.chip(i);
                // Dies keep aggregate stats, not a latency reservoir:
                // mean busy time stands in for p50, worst case for p99.
                let mut t = MetricsTree::leaf(
                    format!("die#{}", self.label_base + i),
                    MetricsSnapshot {
                        requests_admitted: s.served,
                        requests_completed: s.served,
                        trials_executed: s.trials,
                        batches_executed: 0,
                        rows_packed: 0,
                        trials_saved: 0,
                        engine_errors: 0,
                        latency_p50_us: s.mean_latency_us() as u64,
                        latency_p99_us: s.max_latency_us,
                    },
                );
                t.notes.service_us = Some(s.mean_latency_us());
                t.notes.queue_wait_us = Some(s.mean_wait_us());
                t.notes.probe_accuracy = h.rolling_accuracy();
                t.notes.evicted = Some(h.evicted);
                t.notes.weight = weights.get(i).copied();
                t
            })
            .collect();
        MetricsTree::leaf(format!("replicate ×{}", self.txs.len()), self.metrics())
            .with_children(children)
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        Some(self.journal.clone())
    }

    fn shutdown(self: Box<Self>) {
        // Drop closes the queues; workers drain in-flight jobs and exit.
        drop(self);
    }
}

impl Drop for ReplicatedFleetBackend {
    fn drop(&mut self) {
        self.txs.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop<E: TrialEngine>(
    mut chip: Chip<E>,
    rx: mpsc::Receiver<Job>,
    shared: Arc<Shared>,
    metrics: Arc<Metrics>,
    cal: Option<Arc<(Dataset, Calibrator)>>,
    opts: ReplicatedOptions,
) {
    let id = chip.id;
    let reweigh_every = opts.reweigh_every.max(1);
    let label = format!("die#{}", opts.label_base + id);
    let journal = opts.journal.clone().unwrap_or_else(|| Journal::new(DEFAULT_CAPACITY));
    while let Ok(job) = rx.recv() {
        // Health monitor flagged this die as drifting → recalibrate on
        // our own thread before taking the next request.
        if shared.recal[id].swap(false, Relaxed) {
            if let Some(cal) = &cal {
                cal.1.calibrate_chip(&mut chip, &cal.0);
                shared.health.lock().unwrap().note_recalibrated(id);
            }
        }

        // Shed expired work before the kernel runs: the budget covers
        // queue wait too, and trials nobody will read are pure waste.
        if job.req.past_deadline(job.submitted.elapsed()) {
            journal.record(
                EventKind::DeadlineExceeded,
                &label,
                format!("id {}: shed pre-kernel", job.req.id),
            );
            metrics.engine_errors.fetch_add(1, Relaxed);
            shared.loads[id].fetch_sub(1, Relaxed);
            let _ = job.reply.send(InferResponse::failed(
                job.req.id,
                crate::serve::deadline_exceeded_msg(
                    &label,
                    job.submitted.elapsed(),
                    job.req.deadline_ms.unwrap_or(0),
                ),
            ));
            continue;
        }

        let base = trial_stream_base(opts.seed, job.req.id);
        let params = chip.params;
        let service_t0 = Instant::now();
        let mut outcome = WtaOutcome::new(chip.engine.output_dim());
        if job.req.confidence <= 0.0 {
            // Fixed budget: one engine call, so `NativeEngine::infer` can
            // reuse its cached layer-0 pre-activation across every trial.
            outcome = chip
                .engine
                .infer(&job.req.image, params, job.req.max_trials as usize, base);
        } else {
            // Early stopping: vote in min_trials-sized chunks — the engine
            // still amortizes the input layer between Wilson checks, and
            // trial indices stay `base + k` so votes are bit-identical to
            // an unchunked run.
            let chunk = opts.min_trials.max(1);
            while (outcome.trials as u32) < job.req.max_trials {
                let take = chunk.min(job.req.max_trials - outcome.trials as u32);
                let part = chip.engine.infer(
                    &job.req.image,
                    params,
                    take as usize,
                    base.wrapping_add(outcome.trials),
                );
                outcome.merge(&part);
                let (lead, runner) = outcome.top_two();
                if lead_is_decided(lead, runner, job.req.confidence) {
                    break;
                }
            }
        }
        let used = outcome.trials as u32;

        // Health/stats get on-chip *service* time (die speed); the
        // response and backend metrics keep end-to-end latency, which
        // includes queue wait.
        let service_us = service_t0.elapsed().as_micros() as u64;
        let latency = job.submitted.elapsed();
        let prediction = outcome.prediction();
        let abstained = outcome.abstentions == outcome.trials;
        let correct = job.req.label.map(|l| prediction == l);

        // Probe trials are real engine work (counted); probes are not
        // caller traffic (requests/latency stay caller-only).
        metrics.trials_executed.fetch_add(used as u64, Relaxed);
        if !job.probe {
            metrics.trials_saved.fetch_add((job.req.max_trials - used) as u64, Relaxed);
            metrics.requests_completed.fetch_add(1, Relaxed);
            metrics.record_latency(latency);
        }
        // A zero-budget request executed nothing: answering it must not
        // charge the die an abstention/miss (the pipelined backend's
        // zero-budget path likewise bypasses all per-die accounting).
        if job.req.max_trials > 0 {
            shared.health.lock().unwrap().record(id, correct, abstained, service_us);
            let mut stats = shared.stats.lock().unwrap();
            stats[id].record(used as u64, abstained, correct, service_us);
            stats[id].record_wait((latency.as_micros() as u64).saturating_sub(service_us));
        }
        if job.probe {
            let verdict = match correct {
                Some(true) => "hit",
                Some(false) => "miss",
                None => "unlabeled",
            };
            journal.record(EventKind::ProbeVerdict, &label, format!("id {} {verdict}", job.req.id));
        } else {
            journal.record(
                EventKind::RequestCompleted,
                &label,
                format!("id {} trials {used}", job.req.id),
            );
        }
        shared.loads[id].fetch_sub(1, Relaxed);
        let _ = job.reply.send(InferResponse {
            id: job.req.id,
            prediction,
            outcome,
            trials_used: used,
            latency,
            error: None,
        });

        // Periodic live steering: evict floor-breakers, flag drifters for
        // recalibration, refresh the router's traffic weights.
        let done = shared.completed.fetch_add(1, Relaxed) + 1;
        if done % reweigh_every == 0 {
            let steer = shared.health.lock().unwrap().steer();
            for c in steer.drifting {
                shared.recal[c].store(true, Relaxed);
            }
            *shared.weights.lock().unwrap() = steer.weights;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::VariationModel;
    use crate::fleet::RoutePolicy;
    use crate::nn::{ModelSpec, Weights};

    fn backend(chips: usize, policy: RoutePolicy) -> ReplicatedFleetBackend {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let fleet =
            Fleet::program_native(&w, chips, &VariationModel::lognormal(0.05), policy, 99);
        ReplicatedFleetBackend::start(fleet, None, ReplicatedOptions::default())
    }

    #[test]
    fn round_robin_spreads_requests_across_workers() {
        let b = backend(3, RoutePolicy::RoundRobin);
        let mut tickets = Vec::new();
        for i in 0..9u64 {
            let img = vec![(i % 5) as f32 / 5.0; 784];
            tickets.push(b.submit(InferRequest::new(i, img).with_budget(4, 0.0)).unwrap());
        }
        for t in tickets {
            let r = b.wait(t).unwrap();
            assert_eq!(r.trials_used, 4);
        }
        let snap = b.snapshot();
        assert_eq!(snap.aggregate().served, 9);
        assert_eq!(snap.load_imbalance(), 0, "round-robin must balance: {snap}");
        assert_eq!(b.metrics().requests_completed, 9);
        assert_eq!(b.metrics().trials_executed, 36);
    }

    #[test]
    fn responses_are_independent_of_fleet_width() {
        // Trial *indices* depend only on (seed, id); the noise stream at
        // those indices is the serving die's own.  With zero variation
        // and every die pinned to one RNG identity, a 1-die and a 3-die
        // fleet must return bit-identical votes — isolating the index
        // derivation from routing.
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let votes = |chips: usize| -> Vec<Vec<u64>> {
            let fleet = Fleet::program_native(
                &w,
                chips,
                &VariationModel::default(),
                RoutePolicy::RoundRobin,
                7,
            );
            // Zero-variation dies still have distinct engine seeds, so pin
            // every chip to the same trial-RNG identity for this check.
            let mut fleet = fleet;
            for c in fleet.chips.iter_mut() {
                c.engine.seed = 7;
            }
            let b = ReplicatedFleetBackend::start(fleet, None, ReplicatedOptions::default());
            let tickets: Vec<_> = (0..6u64)
                .map(|i| {
                    let img = vec![(i % 3) as f32 / 3.0; 784];
                    b.submit(InferRequest::new(i, img).with_budget(8, 0.0)).unwrap()
                })
                .collect();
            tickets.into_iter().map(|t| b.wait(t).unwrap().outcome.counts).collect()
        };
        assert_eq!(votes(1), votes(3));
    }

    #[test]
    fn labeled_probes_drive_health_and_weights() {
        let b = backend(2, RoutePolicy::Weighted);
        let mut tickets = Vec::new();
        for i in 0..40u64 {
            let img = vec![(i % 7) as f32 / 7.0; 784];
            // Label everything 0 — some will be wrong, which is fine; the
            // point is that the monitor accumulates labeled samples.
            tickets.push(
                b.submit(InferRequest::new(i, img).with_budget(3, 0.0).with_label(0)).unwrap(),
            );
        }
        for t in tickets {
            b.wait(t).unwrap();
        }
        let h = b.shared.health.lock().unwrap();
        let labeled: usize = (0..2).map(|c| h.chip(c).labeled_samples()).sum();
        assert_eq!(labeled, 40);
        drop(h);
        assert_eq!(b.traffic_weights().len(), 2);
    }

    #[test]
    fn probe_injection_feeds_health_from_unlabeled_traffic() {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let fleet = Fleet::program_native(
            &w,
            2,
            &VariationModel::lognormal(0.05),
            RoutePolicy::RoundRobin,
            99,
        );
        let cal = crate::dataset::synth::generate(12, 0xCA1);
        let b = ReplicatedFleetBackend::start(
            fleet,
            Some((cal, Calibrator::quick(3))),
            ReplicatedOptions { probe_rate: 0.5, ..Default::default() },
        );
        // Callers never label anything — probes must close the gap.
        let tickets: Vec<_> = (0..10u64)
            .map(|i| {
                let img = vec![(i % 5) as f32 / 5.0; 784];
                b.submit(InferRequest::new(i, img).with_budget(3, 0.0)).unwrap()
            })
            .collect();
        for t in tickets {
            b.wait(t).unwrap();
        }
        assert_eq!(b.probes_sent(), 5, "rate 0.5 over 10 requests");
        // Caller-facing request metrics exclude probes; trial counters
        // include them (probes run real trials: 10×3 + 5×3).
        let m = b.metrics();
        assert_eq!(m.requests_admitted, 10);
        assert_eq!(m.requests_completed, 10);
        let shared = b.shared.clone();
        Box::new(b).shutdown(); // flush in-flight probes deterministically
        let h = shared.health.lock().unwrap();
        let labeled: usize = (0..2).map(|c| h.chip(c).labeled_samples()).sum();
        assert_eq!(labeled, 5, "every probe reached the health monitor");
    }

    #[test]
    fn metrics_tree_lists_one_child_per_die_with_notes() {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let fleet =
            Fleet::program_native(&w, 3, &VariationModel::lognormal(0.05), RoutePolicy::RoundRobin, 99);
        let b = ReplicatedFleetBackend::start(
            fleet,
            None,
            ReplicatedOptions { label_base: 4, ..Default::default() },
        );
        let tickets: Vec<_> = (0..6u64)
            .map(|i| b.submit(InferRequest::new(i, vec![0.2; 784]).with_budget(3, 0.0)).unwrap())
            .collect();
        for t in tickets {
            b.wait(t).unwrap();
        }
        let tree = b.metrics_tree();
        assert_eq!(tree.children.len(), 3);
        // label_base shifts die names into fleet-wide numbering.
        assert_eq!(tree.children[0].label, "die#4");
        assert_eq!(tree.children[2].label, "die#6");
        let per_die: u64 = tree.children.iter().map(|c| c.snapshot.requests_completed).sum();
        assert_eq!(per_die, 6);
        for c in &tree.children {
            assert_eq!(c.notes.evicted, Some(false));
            assert!(c.notes.queue_wait_us.is_some());
            assert!(c.notes.weight.is_some());
        }
        // Admissions and completions flow into the shared journal.
        let j = b.journal().expect("replicated backend always has a journal");
        let evs = j.tail(64);
        assert!(evs.iter().any(|e| e.kind == crate::telemetry::EventKind::RequestAdmitted));
        assert!(evs.iter().any(|e| e.kind == crate::telemetry::EventKind::RequestCompleted
            && e.node.starts_with("die#")));
    }

    #[test]
    fn shutdown_completes_in_flight_work() {
        let b = Box::new(backend(2, RoutePolicy::LeastLoaded));
        let t = b.submit(InferRequest::new(1, vec![0.3; 784]).with_budget(6, 0.0)).unwrap();
        let rx_alive = t; // hold the ticket across shutdown
        b.shutdown();
        // The worker finished the job before exiting.
        let r = rx_alive.rx.recv().unwrap();
        assert_eq!(r.trials_used, 6);
    }
}
