//! [`Backend`] #1: one chip behind the coordinator's batched scheduler.
//!
//! Thin adapter over [`crate::coordinator::Server`]: the scheduler thread
//! packs (request, trial) pairs into batches, runs them on a single
//! [`TrialRunner`] engine and applies Wilson-interval early stopping.
//! This is the deployment shape of PR-0/PR-1's `raca infer`, now reached
//! through the same trait as the fleet backends.

use std::sync::{mpsc, Arc};

use anyhow::Result;

use crate::coordinator::{MetricsSnapshot, Server, SchedulerConfig, TrialRunner};
use crate::telemetry::{EventKind, Journal, MetricsTree};

use super::{Backend, InferRequest, InferResponse};

/// Single-die serving session (scheduler thread + batched engine).
pub struct SingleChipBackend {
    server: Server,
    /// Telemetry name ([`crate::serve::plan::node_label`] sets the
    /// fleet-wide `die#<chip>`; a bare backend is just `die`).
    label: String,
    journal: Option<Arc<Journal>>,
}

impl SingleChipBackend {
    /// Spawn the scheduler loop over `engine`.
    ///
    /// Crate-private: deployments are built by [`crate::serve::plan`]
    /// (callers that already hold an engine — e.g. a PJRT handle — go
    /// through [`crate::serve::plan::single_die`]).
    pub(crate) fn start<E: TrialRunner + Send + 'static>(engine: E, cfg: SchedulerConfig) -> Self {
        Self { server: Server::start(engine, cfg), label: "die".to_string(), journal: None }
    }

    /// Name this die in the telemetry tree and route its admission
    /// events into the deployment's shared journal.
    pub(crate) fn with_telemetry(mut self, label: impl Into<String>, journal: Arc<Journal>) -> Self {
        self.label = label.into();
        self.journal = Some(journal);
        self
    }
}

impl Backend for SingleChipBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        if let Some(j) = &self.journal {
            j.record(EventKind::RequestAdmitted, &self.label, format!("id {}", req.id));
        }
        self.server.client().submit_request_to(req, reply)
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.server.metrics().snapshot()
    }

    fn metrics_tree(&self) -> MetricsTree {
        MetricsTree::leaf(self.label.clone(), self.metrics())
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        self.journal.clone()
    }

    fn shutdown(self: Box<Self>) {
        // Server::drop signals the scheduler thread and joins it after
        // in-flight requests complete.
        drop(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::nn::{ModelSpec, Weights};

    fn backend() -> SingleChipBackend {
        let w = std::sync::Arc::new(Weights::random(ModelSpec::new(vec![784, 16, 10]), 3));
        let mut cfg = SchedulerConfig::default();
        cfg.batch_size = 16;
        SingleChipBackend::start(NativeEngine::new(w, 7), cfg)
    }

    #[test]
    fn submit_wait_roundtrip() {
        let b = backend();
        let t = b
            .submit(InferRequest::new(1, vec![0.5; 784]).with_budget(9, 0.0))
            .unwrap();
        assert_eq!(t.id, 1);
        let r = b.wait(t).unwrap();
        assert_eq!(r.id, 1);
        assert_eq!(r.trials_used, 9);
        assert!((-1..10).contains(&r.prediction));
        assert_eq!(b.metrics().requests_completed, 1);
    }

    #[test]
    fn works_as_a_trait_object() {
        let b: Box<dyn Backend> = Box::new(backend());
        let mut tickets = Vec::new();
        for i in 0..4u64 {
            tickets.push(
                b.submit(InferRequest::new(i, vec![0.1 * i as f32; 784]).with_budget(5, 0.0))
                    .unwrap(),
            );
        }
        for t in tickets {
            assert_eq!(b.wait(t).unwrap().trials_used, 5);
        }
        let m = b.metrics();
        assert_eq!(m.requests_completed, 4);
        assert_eq!(m.trials_executed, 20);
        b.shutdown();
    }
}
