//! Auto-probe traffic: synthesized labeled requests that keep health
//! monitors fed when callers never send labels.
//!
//! The fleet backends reweight traffic by *accuracy* only when requests
//! carry ground-truth labels ([`InferRequest::with_label`]) — live
//! traffic never does.  A [`ProbeInjector`] closes that gap (the ROADMAP
//! open item): it holds a slice of the held-out calibration set and, at a
//! configurable rate (`serve.probe_rate` probes per caller request, in
//! [0, 1]), emits a labeled probe request alongside real traffic.  Probes
//! ride the normal dispatch path — router pick, worker execution, health
//! recording — so the accuracy signal measures exactly what live requests
//! experience; their responses are discarded and they are excluded from
//! the caller-facing request metrics (trial counters still include them:
//! probe trials are real engine work).
//!
//! Probe ids live in a reserved upper half of the id space
//! ([`PROBE_ID_BASE`]) so they can never collide with caller request ids;
//! the wire codec encodes ids as strings precisely so these full-width
//! ids survive JSON.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::Mutex;

use crate::dataset::Dataset;

use super::{InferRequest, RequestId};

/// Probe ids occupy `[2^63, 2^64)`; callers own everything below.
pub const PROBE_ID_BASE: RequestId = 1 << 63;

/// Id-lane width per injector: each [`ProbeInjector`] instance numbers
/// its probes from `PROBE_ID_BASE + lane·2^44`, so nested probed routers
/// in one process (each level owns an injector) can never collide on an
/// in-flight probe id.  2^19 lanes × 2^44 probes each.
const LANE_SHIFT: u32 = 44;
const LANE_MASK: u64 = (1 << (63 - LANE_SHIFT)) - 1;

/// Process-wide lane allocator.
static INJECTOR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Deterministic probe source: cycles through a labeled dataset, one
/// probe per `1/rate` caller submissions (fractional credit accumulates).
pub struct ProbeInjector {
    set: Dataset,
    rate: f64,
    /// First id of this injector's reserved lane.
    id_base: RequestId,
    credit: Mutex<f64>,
    cursor: AtomicUsize,
    next_id: AtomicU64,
    sent: AtomicU64,
}

impl ProbeInjector {
    /// `None` when probing is disabled (`rate <= 0`) or there is nothing
    /// to probe with.  Rates above 1 are clamped: at most one probe per
    /// caller request (config validation enforces the same bound).
    pub fn new(set: Dataset, rate: f64) -> Option<Self> {
        if !(rate > 0.0) || set.is_empty() {
            return None;
        }
        let lane = INJECTOR_SEQ.fetch_add(1, Relaxed) & LANE_MASK;
        Some(Self {
            set,
            rate: rate.min(1.0),
            id_base: PROBE_ID_BASE + (lane << LANE_SHIFT),
            credit: Mutex::new(0.0),
            cursor: AtomicUsize::new(0),
            next_id: AtomicU64::new(0),
            sent: AtomicU64::new(0),
        })
    }

    /// Whether an id belongs to the reserved probe space.
    pub fn is_probe(id: RequestId) -> bool {
        id >= PROBE_ID_BASE
    }

    /// Probes emitted so far.
    pub fn sent(&self) -> u64 {
        self.sent.load(Relaxed)
    }

    /// Call once per caller submission; returns a labeled probe request
    /// when enough credit has accumulated.  The probe mirrors the
    /// triggering request's trial budget (fixed spend — confidence 0 —
    /// so the health monitor's latency signal is comparable across dies).
    pub fn next(&self, max_trials: u32) -> Option<InferRequest> {
        {
            let mut c = self.credit.lock().unwrap();
            *c += self.rate;
            if *c < 1.0 {
                return None;
            }
            *c -= 1.0;
        }
        let i = self.cursor.fetch_add(1, Relaxed) % self.set.len();
        let id = self.id_base + self.next_id.fetch_add(1, Relaxed);
        self.sent.fetch_add(1, Relaxed);
        Some(
            InferRequest::new(id, self.set.image(i).to_vec())
                .with_budget(max_trials.max(1), 0.0)
                .with_label(self.set.label(i)),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn disabled_rates_and_empty_sets_yield_no_injector() {
        let ds = synth::generate(8, 1);
        assert!(ProbeInjector::new(ds.clone(), 0.0).is_none());
        assert!(ProbeInjector::new(ds.clone(), -1.0).is_none());
        assert!(ProbeInjector::new(ds, f64::NAN).is_none());
        assert!(ProbeInjector::new(ds_empty(), 0.5).is_none());
    }

    fn ds_empty() -> Dataset {
        Dataset { images: Vec::new(), labels: Vec::new() }
    }

    #[test]
    fn fractional_rate_accumulates_credit() {
        let p = ProbeInjector::new(synth::generate(8, 1), 0.25).unwrap();
        let fired: Vec<bool> = (0..8).map(|_| p.next(4).is_some()).collect();
        // One probe per four submissions, deterministically.
        assert_eq!(fired.iter().filter(|&&f| f).count(), 2);
        assert_eq!(p.sent(), 2);
    }

    #[test]
    fn probes_are_labeled_cycled_and_id_reserved() {
        let ds = synth::generate(3, 2);
        let p = ProbeInjector::new(ds.clone(), 1.0).unwrap();
        let base = p.next(6).unwrap().id;
        assert!(ProbeInjector::is_probe(base));
        for k in 1..5u64 {
            let probe = p.next(6).unwrap();
            assert!(ProbeInjector::is_probe(probe.id));
            // Sequential within this injector's reserved lane.
            assert_eq!(probe.id, base + k);
            let i = (k as usize) % ds.len();
            assert_eq!(probe.label, Some(ds.label(i)));
            assert_eq!(probe.image, ds.image(i));
            assert_eq!(probe.max_trials, 6);
            assert_eq!(probe.confidence, 0.0);
        }
        assert!(!ProbeInjector::is_probe(0));
        assert!(!ProbeInjector::is_probe(PROBE_ID_BASE - 1));
    }

    #[test]
    fn injectors_get_disjoint_id_lanes() {
        // Nested probed routers each own an injector; their in-flight
        // probe ids must never collide with one another.
        let ds = synth::generate(2, 4);
        let a = ProbeInjector::new(ds.clone(), 1.0).unwrap();
        let b = ProbeInjector::new(ds, 1.0).unwrap();
        let ia = a.next(4).unwrap().id;
        let ib = b.next(4).unwrap().id;
        assert_ne!(ia, ib, "two injectors shared an id lane");
        assert!(ia.abs_diff(ib) >= 1 << LANE_SHIFT);
        assert!(ProbeInjector::is_probe(ia) && ProbeInjector::is_probe(ib));
    }

    #[test]
    fn rates_above_one_clamp_to_one_probe_per_request() {
        let p = ProbeInjector::new(synth::generate(4, 3), 7.5).unwrap();
        for _ in 0..4 {
            assert!(p.next(4).is_some());
        }
        assert_eq!(p.sent(), 4);
    }
}
