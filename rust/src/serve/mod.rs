//! Serving layer: one `Backend` session API over every deployment shape,
//! described by a composable [`Topology`] tree.
//!
//! The paper's architecture is explicitly configurable — "the number of
//! neural network layers and specifications supported by this architecture
//! can be flexibly configured" (§III-C) — and at system level the same
//! flexibility applies to how dies are composed into a service (Marinella
//! et al.'s multiscale co-design; the tiled/pipelined organizations in
//! Smagulova et al.'s survey).  Replication and pipelining are orthogonal
//! axes, so the deployment is a *tree*, not a flat switch:
//!
//! ```text
//!              Topology ──compile──▶ DeployPlan ──build──▶ Box<dyn Backend>
//!
//!   "2x(pipeline:3)"        replicate × 2 (router + health reweighting)
//!                           ├─ pipeline × 3 dies [chips 0..3]
//!                           │    activations stream die-to-die
//!                           └─ pipeline × 3 dies [chips 3..6]
//!
//!   leaves:      die[:native|physical|pjrt]   pipeline:<dies>[:b<batch>]
//!                remote:<host:port>           (a peer's --listen socket)
//!   combinators: <n>x(<node>)[@policy]        (nests to any depth)
//!                (<node>, <node>, …)[@policy] (route across distinct children)
//! ```
//!
//! Trees span hosts: the [`net`] wire layer serves any compiled topology
//! behind `raca serve --listen <addr>`, and a `remote:` leaf compiles to
//! a [`net::RemoteBackend`] speaking length-prefixed JSON frames — so
//! `(remote:a, remote:b)` health-steers across machines with the same
//! router code that steers local replicas.
//!
//! Every shape speaks the same [`Backend`] session API (`submit` →
//! [`Ticket`] → `wait`), reports the coordinator's [`MetricsSnapshot`],
//! and derives per-request trial streams from
//! [`trial_stream_base`]`(seed, id)` — the parity discipline that makes a
//! pipeline's votes bit-identical to the unsharded engine at equal
//! `(seed, trial_idx)`, wherever the leaf sits in the tree.
//!
//! [`BackendKind`] (`single|replicated|pipelined`) survives as parse-only
//! compatibility sugar: each spelling maps onto its canonical tree via
//! [`BackendKind::to_topology`], and [`plan`] compiles the tree.  The
//! concrete backend types ([`SingleChipBackend`],
//! [`ReplicatedFleetBackend`], [`PipelinedFleetBackend`],
//! [`plan::RouterBackend`]) are constructed only by [`plan`].

pub mod http;
pub mod net;
pub mod pipelined;
pub mod plan;
pub mod probe;
pub mod replicated;
pub mod request;
pub mod single;

pub use http::{serve_http, HttpConfig, HttpServer};
pub use net::{NetServer, RemoteBackend};
pub use pipelined::{PipelineOptions, PipelinedFleetBackend};
pub use plan::{build, BuildOptions, DeployPlan, EngineSel, PlanNode, RouterBackend, Topology};
pub use probe::ProbeInjector;
pub use replicated::{ReplicatedFleetBackend, ReplicatedOptions};
pub use request::{
    deadline_exceeded_msg, InferRequest, InferResponse, RequestId, DEADLINE_EXCEEDED,
};
pub use single::SingleChipBackend;

use std::sync::mpsc;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::MetricsSnapshot;
use crate::fleet::RoutePolicy;
use crate::telemetry::{Journal, MetricsTree};

/// Claim ticket for a submitted request: hold it, do other work, then
/// [`Backend::wait`] on it.  The thread-based analogue of a future.
pub struct Ticket {
    pub id: RequestId,
    rx: mpsc::Receiver<InferResponse>,
}

impl Ticket {
    pub(crate) fn new(id: RequestId, rx: mpsc::Receiver<InferResponse>) -> Self {
        Self { id, rx }
    }
}

/// A serving session: submit/await classification requests against some
/// arrangement of RACA dies.  `Box<dyn Backend>` is what
/// [`plan::build`] returns for any [`Topology`]
/// (`raca serve --topology "2x(pipeline:3)"`) — including trees whose
/// leaves live on other hosts (`remote:<host:port>` ⇒
/// [`net::RemoteBackend`]).  `Sync` because one backend serves many
/// concurrent callers: the network listener shares it across every
/// client connection.
pub trait Backend: Send + Sync {
    /// The submission primitive: admit a request and deliver its
    /// response to `reply`.  Request ids must be unique among in-flight
    /// requests of this backend.
    ///
    /// Callers hand in the channel (rather than receiving a fresh one)
    /// so that *many* requests can share one completion channel — what
    /// lets routers and network sessions multiplex all their in-flight
    /// tickets over a single relay thread, delivering responses in
    /// completion order with no per-request threads and no head-of-line
    /// blocking.
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()>;

    /// Admit a request; returns a [`Ticket`] to wait on.
    fn submit(&self, req: InferRequest) -> Result<Ticket> {
        let id = req.id;
        let (tx, rx) = mpsc::channel();
        self.submit_to(req, tx)?;
        Ok(Ticket::new(id, rx))
    }

    /// Block until the ticketed request completes.  A response carrying
    /// an in-band [`InferResponse::error`] (the request was admitted but
    /// could not be served — dead remote peer, duplicate id) surfaces as
    /// an `Err`, exactly like a dropped reply channel.
    fn wait(&self, ticket: Ticket) -> Result<InferResponse> {
        let id = ticket.id;
        let resp = ticket
            .rx
            .recv()
            .map_err(|_| anyhow!("backend dropped request {id}"))?;
        if let Some(e) = &resp.error {
            bail!("request {id} failed: {e}");
        }
        Ok(resp)
    }

    /// Submit and block for the answer.
    fn classify(&self, req: InferRequest) -> Result<InferResponse> {
        let t = self.submit(req)?;
        self.wait(t)
    }

    /// Aggregate serving metrics since start.
    fn metrics(&self) -> MetricsSnapshot;

    /// Per-node metrics, shaped like the deployment tree: this node's
    /// own snapshot plus one labeled subtree per child (`die#3`,
    /// `stage1`, `remote:host:port`).  Leaves fall back to a single
    /// node wrapping [`Backend::metrics`]; composite backends (router,
    /// pipeline, remote) override to expose their children, annotated
    /// with service-time vs. queue-wait, probe accuracy, eviction state
    /// and in-band error counts ([`crate::telemetry::NodeNotes`]).
    fn metrics_tree(&self) -> MetricsTree {
        MetricsTree::leaf("die", self.metrics())
    }

    /// The deployment tree's shared event [`Journal`], if this backend
    /// writes one (topologies built by [`plan::build`] all share one
    /// ring; hand-constructed backends may have none).
    fn journal(&self) -> Option<std::sync::Arc<Journal>> {
        None
    }

    /// Finish in-flight work and tear the session down (worker threads are
    /// joined).  Dropping a backend has the same effect; `shutdown` makes
    /// the point explicit for `Box<dyn Backend>` callers.
    fn shutdown(self: Box<Self>);
}

/// One backend behind several front doors: wrap a shared `Arc` so each
/// listener (`NetServer`, `HttpServer`) gets its own `Box<dyn Backend>`
/// over the *same* session — `raca serve --listen ... --http ...` serves
/// both protocols from one deployment tree, with one metrics/journal
/// stream.  `shutdown` drops only this handle; the underlying backend
/// tears down when the last holder lets go.
pub struct SharedBackend(pub std::sync::Arc<dyn Backend>);

impl Backend for SharedBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        self.0.submit_to(req, reply)
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.0.metrics()
    }

    fn metrics_tree(&self) -> MetricsTree {
        self.0.metrics_tree()
    }

    fn journal(&self) -> Option<std::sync::Arc<Journal>> {
        self.0.journal()
    }

    fn shutdown(self: Box<Self>) {}
}

/// Legacy deployment-shape spellings, kept as parse-only convenience:
/// each maps onto a canonical [`Topology`] tree
/// ([`BackendKind::to_topology`]); nothing constructs backends from a
/// `BackendKind` directly anymore.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Single,
    Replicated,
    Pipelined,
}

impl BackendKind {
    /// Accepted spellings, for error messages.
    pub const SPELLINGS: &'static str = "single, replicated, pipelined";

    /// Parse a CLI/config spelling (case-insensitive).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "single" => Some(BackendKind::Single),
            "replicated" => Some(BackendKind::Replicated),
            "pipelined" => Some(BackendKind::Pipelined),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Single => "single",
            BackendKind::Replicated => "replicated",
            BackendKind::Pipelined => "pipelined",
        }
    }

    /// The canonical topology tree of this legacy spelling:
    /// `single` ⇒ `die`, `replicated` ⇒ `<chips>x(die)`,
    /// `pipelined` ⇒ `pipeline:<shards>`.
    pub fn to_topology(self, chips: usize, shards: usize, policy: RoutePolicy) -> Topology {
        match self {
            BackendKind::Single => Topology::Die { engine: EngineSel::Native },
            BackendKind::Replicated => Topology::Replicate {
                n: chips,
                policy,
                child: Box::new(Topology::Die { engine: EngineSel::Native }),
            },
            BackendKind::Pipelined => Topology::Pipeline { shards, batch: None },
        }
    }
}

/// The `"serve"` config block: which deployment tree `raca serve` builds,
/// and how big.  Parsed by [`crate::config::RunConfig`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Legacy shape selector (compatibility sugar over [`Topology`]).
    pub backend: BackendKind,
    /// Explicit deployment tree (`"topology": "2x(pipeline:3)"`); wins
    /// over `backend`/`chips`/`shards` when set.
    pub topology: Option<Topology>,
    /// Replicas for the legacy `replicated` spelling.
    pub chips: usize,
    /// Dies for the legacy `pipelined` spelling (≤ the model's layers).
    pub shards: usize,
    /// Pipeline flow-control window (trials in flight).
    pub depth: usize,
    /// Default trials per die-to-die message for pipeline leaves.
    pub batch: usize,
    /// Trials per blocked-kernel pass on native dies (`--trial-block`;
    /// ≥ 1, default 64 = one `u64` lane).  Performance-only: votes are
    /// bit-identical at any value.
    pub trial_block: usize,
    /// Labeled health probes injected per caller request, in [0, 1]
    /// (0 disables).  Probes come from the held-out calibration slice, so
    /// accuracy-based health steering works even when callers never send
    /// labels ([`probe::ProbeInjector`]).
    pub probe_rate: f64,
    /// Host a listener instead of pushing a local workload:
    /// `raca serve --listen <addr>` / `"serve": {"listen": "..."}` —
    /// the compiled topology goes behind a [`net::NetServer`] socket.
    pub listen: Option<String>,
    /// Host the HTTP/JSON ingress (`raca serve --http <addr>` /
    /// `"serve": {"http": {...}}`) — the compiled topology goes behind a
    /// [`http::HttpServer`] with admission control and continuous
    /// batching.  Composable with `listen`: both front doors can share
    /// one backend.
    pub http: Option<HttpConfig>,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            backend: BackendKind::Single,
            topology: None,
            chips: 4,
            shards: 2,
            depth: 256,
            batch: 8,
            trial_block: crate::engine::DEFAULT_TRIAL_BLOCK,
            probe_rate: 0.0,
            listen: None,
            http: None,
            seed: 0x5EB0E,
        }
    }
}

impl ServeConfig {
    /// The deployment tree this config selects: an explicit `topology`
    /// wins; otherwise the legacy knobs map onto their canonical trees.
    pub fn tree(&self, policy: RoutePolicy) -> Topology {
        self.topology
            .clone()
            .unwrap_or_else(|| self.backend.to_topology(self.chips, self.shards, policy))
    }
}

/// Base trial index of a request's RNG stream: 2^32 indices per request,
/// so per-request streams stay disjoint for any realistic trial budget
/// (the fleet-wide idiom — calibration and serving use the same shape).
/// Backends derive every trial of request `id` as `base + t`, which is
/// what makes sharded execution reproduce the unsharded
/// [`crate::engine::NativeEngine`] vote-for-vote at equal seeds — at any
/// position in a deployment tree.
pub fn trial_stream_base(seed: u64, id: RequestId) -> u64 {
    seed.wrapping_add(id << 32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_spellings() {
        assert_eq!(BackendKind::parse("single"), Some(BackendKind::Single));
        assert_eq!(BackendKind::parse("replicated"), Some(BackendKind::Replicated));
        assert_eq!(BackendKind::parse("pipelined"), Some(BackendKind::Pipelined));
        // Case-insensitive, like every other CLI/config spelling.
        assert_eq!(BackendKind::parse("Single"), Some(BackendKind::Single));
        assert_eq!(BackendKind::parse("PIPELINED"), Some(BackendKind::Pipelined));
        assert_eq!(BackendKind::parse("sharded"), None);
        assert_eq!(BackendKind::Pipelined.name(), "pipelined");
    }

    #[test]
    fn backend_kinds_map_onto_canonical_trees() {
        let policy = RoutePolicy::RoundRobin;
        assert_eq!(
            BackendKind::Single.to_topology(4, 2, policy).to_string(),
            "die"
        );
        assert_eq!(
            BackendKind::Replicated.to_topology(4, 2, policy).to_string(),
            "4x(die)"
        );
        assert_eq!(
            BackendKind::Pipelined.to_topology(4, 2, policy).to_string(),
            "pipeline:2"
        );
        // ServeConfig resolves the same way, unless an explicit tree wins.
        let mut sc = ServeConfig::default();
        sc.backend = BackendKind::Replicated;
        assert_eq!(sc.tree(policy).to_string(), "4x(die)");
        sc.topology = Some(Topology::parse("2x(pipeline:3)").unwrap());
        assert_eq!(sc.tree(policy).to_string(), "2x(pipeline:3)");
    }

    #[test]
    fn trial_streams_disjoint_across_requests() {
        let a = trial_stream_base(7, 1);
        let b = trial_stream_base(7, 2);
        // 2^32 indices of headroom between consecutive request streams.
        assert_eq!(b.wrapping_sub(a), 1u64 << 32);
    }
}
