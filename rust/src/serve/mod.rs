//! Serving layer: one `Backend` session API over every deployment shape.
//!
//! The paper's architecture is explicitly configurable — "the number of
//! neural network layers and specifications supported by this architecture
//! can be flexibly configured" (§III-C) — and at system level the same
//! flexibility applies to how dies are composed into a service (Marinella
//! et al.'s multiscale co-design; the tiled/pipelined organizations in
//! Smagulova et al.'s survey).  This module is the single entry point for
//! all of it:
//!
//! ```text
//!                          ┌────────────────────────────┐
//!     submit / wait        │        trait Backend       │
//!     metrics / shutdown──▶│  submit(InferRequest)      │
//!                          │    -> Ticket               │
//!                          │  wait(Ticket)              │
//!                          │    -> InferResponse        │
//!                          └──────┬───────┬───────┬─────┘
//!                  ┌──────────────┘       │       └──────────────┐
//!      SingleChipBackend      ReplicatedFleetBackend   PipelinedFleetBackend
//!      Server + Scheduler     per-chip worker threads  layers sharded across
//!      over one TrialRunner   + Router + live health   dies; activations
//!      (batched, early-stop)  reweighting              stream die-to-die
//! ```
//!
//! * [`SingleChipBackend`] — the coordinator's batched scheduler thread
//!   over one engine (native, physical, or — under `pjrt` — XLA);
//! * [`ReplicatedFleetBackend`] — one worker thread per programmed die, a
//!   shared [`crate::fleet::Router`] choosing the die per request, and the
//!   [`crate::fleet::HealthMonitor`] driving *live* traffic reweighting,
//!   recalibration and eviction while the fleet serves;
//! * [`PipelinedFleetBackend`] — one *model* split layer-ranges-per-die
//!   over an [`crate::arch::ShardPlan`], partial activations streamed
//!   die-to-die over channels, so model capacity scales with fleet size.
//!
//! All three speak [`InferRequest`]/[`InferResponse`] (promoted from the
//! coordinator into this shared vocabulary) and report the coordinator's
//! [`MetricsSnapshot`].

pub mod pipelined;
pub mod replicated;
pub mod request;
pub mod single;

pub use pipelined::{PipelineOptions, PipelinedFleetBackend};
pub use replicated::{ReplicatedFleetBackend, ReplicatedOptions};
pub use request::{InferRequest, InferResponse, RequestId};
pub use single::SingleChipBackend;

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::coordinator::MetricsSnapshot;

/// Claim ticket for a submitted request: hold it, do other work, then
/// [`Backend::wait`] on it.  The thread-based analogue of a future.
pub struct Ticket {
    pub id: RequestId,
    rx: mpsc::Receiver<InferResponse>,
}

impl Ticket {
    pub(crate) fn new(id: RequestId, rx: mpsc::Receiver<InferResponse>) -> Self {
        Self { id, rx }
    }
}

/// A serving session: submit/await classification requests against some
/// arrangement of RACA dies.  `Box<dyn Backend>` is the deployment-shape
/// switch (`raca serve --backend single|replicated|pipelined`).
pub trait Backend: Send {
    /// Admit a request; returns a [`Ticket`] to wait on.  Request ids must
    /// be unique among in-flight requests of this backend.
    fn submit(&self, req: InferRequest) -> Result<Ticket>;

    /// Block until the ticketed request completes.
    fn wait(&self, ticket: Ticket) -> Result<InferResponse> {
        let id = ticket.id;
        ticket
            .rx
            .recv()
            .map_err(|_| anyhow!("backend dropped request {id}"))
    }

    /// Submit and block for the answer.
    fn classify(&self, req: InferRequest) -> Result<InferResponse> {
        let t = self.submit(req)?;
        self.wait(t)
    }

    /// Aggregate serving metrics since start.
    fn metrics(&self) -> MetricsSnapshot;

    /// Finish in-flight work and tear the session down (worker threads are
    /// joined).  Dropping a backend has the same effect; `shutdown` makes
    /// the point explicit for `Box<dyn Backend>` callers.
    fn shutdown(self: Box<Self>);
}

/// Which [`Backend`] implementation a config/CLI run selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    #[default]
    Single,
    Replicated,
    Pipelined,
}

impl BackendKind {
    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "single" => Some(BackendKind::Single),
            "replicated" => Some(BackendKind::Replicated),
            "pipelined" => Some(BackendKind::Pipelined),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Single => "single",
            BackendKind::Replicated => "replicated",
            BackendKind::Pipelined => "pipelined",
        }
    }
}

/// The `"serve"` config block: which deployment shape `raca serve`
/// builds, and how big.  Parsed by [`crate::config::RunConfig`].
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub backend: BackendKind,
    /// Replicas for the replicated backend.
    pub chips: usize,
    /// Dies for the pipelined backend (≤ the model's layer count).
    pub shards: usize,
    /// Pipeline flow-control window (trials in flight).
    pub depth: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { backend: BackendKind::Single, chips: 4, shards: 2, depth: 256, seed: 0x5EB0E }
    }
}

/// Base trial index of a request's RNG stream: 2^32 indices per request,
/// so per-request streams stay disjoint for any realistic trial budget
/// (the fleet-wide idiom — calibration and serving use the same shape).
/// Fleet backends derive every trial of request `id` as `base + t`, which
/// is what makes sharded execution reproduce the unsharded
/// [`crate::engine::NativeEngine`] vote-for-vote at equal seeds.
pub fn trial_stream_base(seed: u64, id: RequestId) -> u64 {
    seed.wrapping_add(id << 32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_spellings() {
        assert_eq!(BackendKind::parse("single"), Some(BackendKind::Single));
        assert_eq!(BackendKind::parse("replicated"), Some(BackendKind::Replicated));
        assert_eq!(BackendKind::parse("pipelined"), Some(BackendKind::Pipelined));
        assert_eq!(BackendKind::parse("sharded"), None);
        assert_eq!(BackendKind::Pipelined.name(), "pipelined");
    }

    #[test]
    fn trial_streams_disjoint_across_requests() {
        let a = trial_stream_base(7, 1);
        let b = trial_stream_base(7, 2);
        // 2^32 indices of headroom between consecutive request streams.
        assert_eq!(b.wrapping_sub(a), 1u64 << 32);
    }
}
