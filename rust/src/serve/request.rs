//! Shared request/response vocabulary of the serving API.
//!
//! Every [`crate::serve::Backend`] speaks these types; the coordinator
//! re-exports them for backward compatibility (they started life there
//! and were promoted when serving grew beyond one chip).

use crate::neuron::WtaOutcome;

pub type RequestId = u64;

/// One classification request.
///
/// `id` must be unique among a backend's in-flight requests — it keys
/// response routing and (for the fleet backends) the request's trial
/// indices.  Equal `(backend seed, id)` reproduce identical votes on the
/// pipelined backend (whose dies share one logical RNG stream); on the
/// replicated backend the votes additionally depend on which die served
/// the request (each die keeps its own RNG identity), so reproducibility
/// holds per fixed fleet shape and routing.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub id: RequestId,
    /// 784 pixels in [0, 1].
    pub image: Vec<f32>,
    /// Trial budget (vote cap).  The paper's Fig. 6 x-axis.
    pub max_trials: u32,
    /// Early-stop confidence on the top-two Wilson interval (0 disables).
    pub confidence: f64,
    /// Ground-truth label for probe traffic (`None` for live traffic).
    /// Labeled requests feed the fleet backends' health monitors.
    pub label: Option<i32>,
}

impl InferRequest {
    pub fn new(id: RequestId, image: Vec<f32>) -> Self {
        Self { id, image, max_trials: 32, confidence: 0.95, label: None }
    }

    pub fn with_budget(mut self, max_trials: u32, confidence: f64) -> Self {
        self.max_trials = max_trials;
        self.confidence = confidence;
        self
    }

    /// Attach a ground-truth label (health-probe traffic).
    pub fn with_label(mut self, label: i32) -> Self {
        self.label = Some(label);
        self
    }
}

/// Completed classification.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub id: RequestId,
    /// Majority-vote class (−1 if every trial abstained).
    pub prediction: i32,
    /// Full vote state (counts, abstentions, trials used).
    pub outcome: WtaOutcome,
    /// Trials actually spent (≤ max_trials when early-stopped).
    pub trials_used: u32,
    /// Wall-clock latency from submit to completion.
    pub latency: std::time::Duration,
    /// In-band failure: the request was admitted but could not be served
    /// (duplicate in-flight id, dead remote peer, …).
    /// [`crate::serve::Backend::wait`] turns this into an `Err`, and the
    /// signal survives shared completion channels — a router relay or a
    /// network session multiplexing many tickets still learns exactly
    /// which request died (a dropped sender could not say).
    pub error: Option<String>,
}

impl InferResponse {
    /// Synthesize a failure response for `id` (zero trials, no votes).
    pub fn failed(id: RequestId, msg: impl Into<String>) -> Self {
        Self {
            id,
            prediction: -1,
            outcome: WtaOutcome::new(0),
            trials_used: 0,
            latency: std::time::Duration::ZERO,
            error: Some(msg.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let r = InferRequest::new(7, vec![0.0; 784]);
        assert_eq!(r.max_trials, 32);
        assert!(r.confidence > 0.9);
        assert_eq!(r.label, None);
        let r = r.with_budget(64, 0.0).with_label(3);
        assert_eq!(r.max_trials, 64);
        assert_eq!(r.confidence, 0.0);
        assert_eq!(r.label, Some(3));
    }
}
