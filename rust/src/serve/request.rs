//! Shared request/response vocabulary of the serving API.
//!
//! Every [`crate::serve::Backend`] speaks these types; the coordinator
//! re-exports them for backward compatibility (they started life there
//! and were promoted when serving grew beyond one chip).

use crate::neuron::WtaOutcome;

pub type RequestId = u64;

/// One classification request.
///
/// `id` must be unique among a backend's in-flight requests — it keys
/// response routing and (for the fleet backends) the request's trial
/// indices.  Equal `(backend seed, id)` reproduce identical votes on the
/// pipelined backend (whose dies share one logical RNG stream); on the
/// replicated backend the votes additionally depend on which die served
/// the request (each die keeps its own RNG identity), so reproducibility
/// holds per fixed fleet shape and routing.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub id: RequestId,
    /// 784 pixels in [0, 1].
    pub image: Vec<f32>,
    /// Trial budget (vote cap).  The paper's Fig. 6 x-axis.
    pub max_trials: u32,
    /// Early-stop confidence on the top-two Wilson interval (0 disables).
    pub confidence: f64,
    /// Ground-truth label for probe traffic (`None` for live traffic).
    /// Labeled requests feed the fleet backends' health monitors.
    pub label: Option<i32>,
    /// Remaining deadline budget in milliseconds (`None` = unbounded).
    /// Set at the edge (HTTP `X-Raca-Deadline-Ms`, wire Submit field) and
    /// decremented as the request propagates down a deployment tree:
    /// routers subtract observed queue wait before relaying, and every
    /// execution stage sheds expired work with an in-band
    /// `deadline_exceeded` failure instead of computing trials nobody
    /// will read.  Each node measures the budget from its own receipt,
    /// so clocks never cross the wire.
    pub deadline_ms: Option<u64>,
}

impl InferRequest {
    pub fn new(id: RequestId, image: Vec<f32>) -> Self {
        Self { id, image, max_trials: 32, confidence: 0.95, label: None, deadline_ms: None }
    }

    pub fn with_budget(mut self, max_trials: u32, confidence: f64) -> Self {
        self.max_trials = max_trials;
        self.confidence = confidence;
        self
    }

    /// Attach a ground-truth label (health-probe traffic).
    pub fn with_label(mut self, label: i32) -> Self {
        self.label = Some(label);
        self
    }

    /// Attach a deadline budget in milliseconds.
    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Whether a request that has already waited `waited` is past its
    /// deadline budget.  Unbounded requests never expire.
    pub fn past_deadline(&self, waited: std::time::Duration) -> bool {
        self.deadline_ms.is_some_and(|d| waited.as_millis() as u64 >= d)
    }
}

/// The canonical in-band failure message for a shed request.  Kept as a
/// prefix contract: the HTTP ingress maps any error starting with this
/// to `504 Gateway Timeout`, and chaos/deadline tests match on it.
pub const DEADLINE_EXCEEDED: &str = "deadline_exceeded";

/// Format the in-band failure for a request shed at `node` after
/// `waited` of a `deadline_ms` budget.
pub fn deadline_exceeded_msg(node: &str, waited: std::time::Duration, deadline_ms: u64) -> String {
    format!(
        "{DEADLINE_EXCEEDED}: {node} shed the request after {}ms of a {deadline_ms}ms budget",
        waited.as_millis()
    )
}

/// Completed classification.
#[derive(Debug, Clone, PartialEq)]
pub struct InferResponse {
    pub id: RequestId,
    /// Majority-vote class (−1 if every trial abstained).
    pub prediction: i32,
    /// Full vote state (counts, abstentions, trials used).
    pub outcome: WtaOutcome,
    /// Trials actually spent (≤ max_trials when early-stopped).
    pub trials_used: u32,
    /// Wall-clock latency from submit to completion.
    pub latency: std::time::Duration,
    /// In-band failure: the request was admitted but could not be served
    /// (duplicate in-flight id, dead remote peer, …).
    /// [`crate::serve::Backend::wait`] turns this into an `Err`, and the
    /// signal survives shared completion channels — a router relay or a
    /// network session multiplexing many tickets still learns exactly
    /// which request died (a dropped sender could not say).
    pub error: Option<String>,
}

impl InferResponse {
    /// Synthesize a failure response for `id` (zero trials, no votes).
    pub fn failed(id: RequestId, msg: impl Into<String>) -> Self {
        Self {
            id,
            prediction: -1,
            outcome: WtaOutcome::new(0),
            trials_used: 0,
            latency: std::time::Duration::ZERO,
            error: Some(msg.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults() {
        let r = InferRequest::new(7, vec![0.0; 784]);
        assert_eq!(r.max_trials, 32);
        assert!(r.confidence > 0.9);
        assert_eq!(r.label, None);
        assert_eq!(r.deadline_ms, None);
        let r = r.with_budget(64, 0.0).with_label(3).with_deadline_ms(250);
        assert_eq!(r.max_trials, 64);
        assert_eq!(r.confidence, 0.0);
        assert_eq!(r.label, Some(3));
        assert_eq!(r.deadline_ms, Some(250));
    }

    #[test]
    fn deadline_expiry_is_exclusive_of_remaining_budget() {
        use std::time::Duration;
        let unbounded = InferRequest::new(1, vec![0.0]);
        assert!(!unbounded.past_deadline(Duration::from_secs(3600)));
        let bounded = InferRequest::new(2, vec![0.0]).with_deadline_ms(100);
        assert!(!bounded.past_deadline(Duration::from_millis(99)));
        assert!(bounded.past_deadline(Duration::from_millis(100)));
        // A zero budget is expired on arrival.
        let zero = InferRequest::new(3, vec![0.0]).with_deadline_ms(0);
        assert!(zero.past_deadline(Duration::ZERO));
    }

    #[test]
    fn deadline_message_carries_the_matchable_prefix() {
        let msg = deadline_exceeded_msg("die#2", std::time::Duration::from_millis(7), 5);
        assert!(msg.starts_with(DEADLINE_EXCEEDED));
        assert!(msg.contains("die#2") && msg.contains("7ms") && msg.contains("5ms"));
    }
}
