//! Admission control for the HTTP ingress: decide *before* spending
//! backend work whether a request may enter.
//!
//! Three gates, cheapest first:
//!
//! 1. **Per-tenant token bucket** — requests carry an `X-Raca-Tenant`
//!    header; each tenant refills at `rate` requests/s up to `burst`
//!    tokens.  Untagged traffic shares one anonymous bucket, so omitting
//!    the header is not a bypass.  A rate of 0 disables the gate.
//! 2. **In-flight budget** — a hard cap on admitted-but-unanswered
//!    requests (queued *or* executing).  Admission hands back an RAII
//!    [`Permit`]; the gauge decrements when the permit drops, i.e. when
//!    the response has been written, so an admitted request can never be
//!    silently dropped without releasing its slot.
//! 3. **Bounded queue** — the batcher's `sync_channel` (owned by the
//!    server, not this module); a full queue is reported back here via
//!    [`Admission::note_shed_queue`] so the shed counters stay in one
//!    place.
//!
//! Every rejection maps to `429 Too Many Requests` + `Retry-After` in
//! [`super::routes`]; nothing in this module blocks.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Longest `Retry-After` hint we'll ever send, seconds.  A tenant so far
/// over budget that the honest wait exceeds this should re-negotiate
/// capacity, not sleep for an hour.
const MAX_RETRY_AFTER_SECS: u64 = 3600;

/// Shared admission state for one listener.
pub struct Admission {
    in_flight_budget: usize,
    in_flight: AtomicUsize,
    /// Permits granted (the queue gate may still shed afterwards).
    admitted: AtomicU64,
    shed_queue: AtomicU64,
    shed_in_flight: AtomicU64,
    shed_rate: AtomicU64,
    /// Tokens/s per tenant; 0 disables rate limiting.
    rate: f64,
    /// Bucket capacity (max burst).
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
}

struct Bucket {
    tokens: f64,
    refilled: Instant,
}

/// RAII in-flight slot: dropping it (response written, or shed at the
/// queue gate) releases the budget.
pub struct Permit {
    adm: Arc<Admission>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.adm.in_flight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Outcome of [`Admission::try_admit`].
pub enum Verdict {
    Admitted(Permit),
    Shed {
        retry_after_secs: u64,
        reason: &'static str,
    },
}

/// Counter snapshot for `GET /metrics`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmissionStats {
    pub admitted: u64,
    pub shed_queue: u64,
    pub shed_in_flight: u64,
    pub shed_rate: u64,
    pub in_flight_now: usize,
}

impl AdmissionStats {
    pub fn shed_total(&self) -> u64 {
        self.shed_queue + self.shed_in_flight + self.shed_rate
    }
}

impl Admission {
    pub fn new(in_flight_budget: usize, tenant_rate: f64, tenant_burst: f64) -> Arc<Self> {
        Arc::new(Self {
            in_flight_budget: in_flight_budget.max(1),
            in_flight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed_queue: AtomicU64::new(0),
            shed_in_flight: AtomicU64::new(0),
            shed_rate: AtomicU64::new(0),
            rate: tenant_rate.max(0.0),
            burst: tenant_burst.max(1.0),
            buckets: Mutex::new(HashMap::new()),
        })
    }

    /// Run the rate and in-flight gates.  On `Admitted`, the caller
    /// holds the in-flight slot until the returned [`Permit`] drops.
    pub fn try_admit(self: &Arc<Self>, tenant: Option<&str>) -> Verdict {
        if self.rate > 0.0 {
            // Untagged traffic shares the "" bucket — anonymous callers
            // compete with each other, not with named tenants.
            let key = tenant.unwrap_or("");
            let mut buckets = self.buckets.lock().unwrap();
            let now = Instant::now();
            let b = buckets
                .entry(key.to_string())
                .or_insert(Bucket { tokens: self.burst, refilled: now });
            let dt = now.duration_since(b.refilled).as_secs_f64();
            b.tokens = (b.tokens + dt * self.rate).min(self.burst);
            b.refilled = now;
            if b.tokens < 1.0 {
                let wait = ((1.0 - b.tokens) / self.rate).ceil().max(1.0);
                self.shed_rate.fetch_add(1, Ordering::Relaxed);
                return Verdict::Shed {
                    retry_after_secs: (wait as u64).min(MAX_RETRY_AFTER_SECS),
                    reason: "tenant rate limit",
                };
            }
            b.tokens -= 1.0;
        }

        let took_slot = self
            .in_flight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < self.in_flight_budget).then_some(n + 1)
            })
            .is_ok();
        if !took_slot {
            self.shed_in_flight.fetch_add(1, Ordering::Relaxed);
            return Verdict::Shed { retry_after_secs: 1, reason: "in-flight budget full" };
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Verdict::Admitted(Permit { adm: self.clone() })
    }

    /// The queue gate shed an already-permitted request (its permit is
    /// being dropped by the caller).
    pub fn note_shed_queue(&self) {
        self.shed_queue.fetch_add(1, Ordering::Relaxed);
    }

    pub fn in_flight_now(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_queue: self.shed_queue.load(Ordering::Relaxed),
            shed_in_flight: self.shed_in_flight.load(Ordering::Relaxed),
            shed_rate: self.shed_rate.load(Ordering::Relaxed),
            in_flight_now: self.in_flight_now(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn admit(adm: &Arc<Admission>, tenant: Option<&str>) -> Result<Permit, (u64, &'static str)> {
        match adm.try_admit(tenant) {
            Verdict::Admitted(p) => Ok(p),
            Verdict::Shed { retry_after_secs, reason } => Err((retry_after_secs, reason)),
        }
    }

    #[test]
    fn in_flight_budget_sheds_and_releases_on_permit_drop() {
        let adm = Admission::new(2, 0.0, 1.0);
        let p1 = admit(&adm, None).unwrap();
        let _p2 = admit(&adm, None).unwrap();
        let (retry, reason) = admit(&adm, None).unwrap_err();
        assert_eq!(reason, "in-flight budget full");
        assert!(retry >= 1);
        assert_eq!(adm.in_flight_now(), 2);

        drop(p1);
        assert_eq!(adm.in_flight_now(), 1);
        let _p3 = admit(&adm, None).unwrap();

        let s = adm.stats();
        assert_eq!(s.admitted, 3);
        assert_eq!(s.shed_in_flight, 1);
        assert_eq!(s.shed_total(), 1);
    }

    #[test]
    fn tenant_buckets_are_independent_and_anonymous_traffic_shares_one() {
        // Tiny refill rate: the burst is all a tenant gets in-test.
        let adm = Admission::new(64, 0.001, 2.0);
        let _a1 = admit(&adm, Some("alice")).unwrap();
        let _a2 = admit(&adm, Some("alice")).unwrap();
        let (retry, reason) = admit(&adm, Some("alice")).unwrap_err();
        assert_eq!(reason, "tenant rate limit");
        assert!(retry >= 1, "honest wait hint, got {retry}");

        // Bob's bucket is untouched by Alice's exhaustion.
        let _b1 = admit(&adm, Some("bob")).unwrap();

        // Untagged requests share the anonymous bucket.
        let _n1 = admit(&adm, None).unwrap();
        let _n2 = admit(&adm, None).unwrap();
        assert!(admit(&adm, None).is_err());

        assert_eq!(adm.stats().shed_rate, 2);
    }

    #[test]
    fn buckets_refill_over_time() {
        let adm = Admission::new(64, 200.0, 1.0);
        let _p = admit(&adm, Some("t")).unwrap();
        assert!(admit(&adm, Some("t")).is_err(), "burst of 1 spent");
        std::thread::sleep(std::time::Duration::from_millis(50));
        // 50 ms at 200 tokens/s ≈ 10 tokens, capped at burst 1.
        assert!(admit(&adm, Some("t")).is_ok(), "bucket should have refilled");
    }

    #[test]
    fn zero_rate_disables_the_limiter() {
        let adm = Admission::new(1024, 0.0, 1.0);
        for _ in 0..100 {
            // Permits dropped immediately: only the rate gate could shed.
            admit(&adm, Some("t")).unwrap();
        }
        assert_eq!(adm.stats().shed_rate, 0);
    }

    #[test]
    fn retry_after_is_capped() {
        // 1 token per ~28 hours: the honest wait is huge, the hint is not.
        let adm = Admission::new(64, 0.00001, 1.0);
        let _p = admit(&adm, Some("t")).unwrap();
        let (retry, _) = admit(&adm, Some("t")).unwrap_err();
        assert!(retry <= MAX_RETRY_AFTER_SECS, "{retry}");
    }
}
