//! `serve::http` — the HTTP/JSON front door.
//!
//! The framed socket ([`crate::serve::net`]) is a fabric-internal
//! protocol: binary length prefixes, versioned handshakes, long-lived
//! sessions.  Public traffic needs the opposite — a protocol any load
//! balancer and `curl` already speak, with explicit backpressure at the
//! edge.  This module is that layer, and it follows the paper's
//! delete-the-periphery discipline end to end:
//!
//! ```text
//!   client ── HTTP/1.1 ──► admission ──► bounded queue ──► batcher ──► Backend
//!                (429 + Retry-After)        (sync_channel)   (merge)     (any)
//! ```
//!
//! - **[`admission`]** decides *cheaply* whether a request may enter:
//!   per-tenant token buckets (`X-Raca-Tenant`), an in-flight budget
//!   enforced by RAII permits, and the bounded queue itself.  Overload
//!   degrades into fast, honest `429`s — never into unbounded memory or
//!   a hung socket.
//! - **[`batcher`]** is the continuous-batching stage: it drains the
//!   queue and submits identical-pixel requests back-to-back so the
//!   scheduler's `group_equal_rows` pass (PR-5) collapses them into one
//!   blocked kernel sweep, regardless of the order clients connected in.
//!   Requests keep their own ids and trial streams, so merging never
//!   changes a single vote.
//! - **[`routes`]** exposes `POST /v1/infer` (lazily parsed —
//!   [`crate::util::json::LazyObject`] extracts `id`/`pixels`/`trials`
//!   without materializing the body), `GET /metrics`, `GET /tree`
//!   (PR-6 telemetry as JSON), and `GET /healthz`.
//! - **[`server`]** is the hand-rolled HTTP/1.1 listener itself:
//!   keep-alive, `Content-Length` bodies capped at the wire layer's
//!   16 MiB, one thread per connection.
//!
//! Surfaced as `raca serve --http <addr>` and the `serve.http` config
//! block; see the README "HTTP ingress" section for curl examples.

pub mod admission;
pub mod batcher;
pub mod routes;
pub mod server;

pub use admission::{Admission, AdmissionStats};
pub use server::{serve_http, HttpServer};

/// Validated `serve.http` settings (config file `serve.http` block
/// and/or the `--http` flag; see `config.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct HttpConfig {
    /// Bind address, `host:port`.
    pub addr: String,
    /// Bounded queue depth between admission and the batcher.
    pub queue_depth: usize,
    /// Max admitted-but-unanswered requests (queued + executing).
    pub in_flight: usize,
    /// Token-bucket refill, requests/s per tenant.  0 disables rate
    /// limiting.
    pub tenant_rate: f64,
    /// Token-bucket capacity (max burst per tenant).
    pub tenant_burst: f64,
}

impl HttpConfig {
    pub fn new(addr: impl Into<String>) -> Self {
        HttpConfig {
            addr: addr.into(),
            queue_depth: 256,
            in_flight: 512,
            tenant_rate: 0.0,
            tenant_burst: 8.0,
        }
    }
}
