//! Continuous batcher: the stage between the admission queue and the
//! backend.
//!
//! A dedicated thread drains the bounded queue — block for the first
//! request, then sweep whatever else has arrived (up to [`MAX_FLUSH`]) —
//! and submits each flush to the backend **grouped by identical image**.
//! This generalizes the scheduler's `group_equal_rows` trick across
//! requests: the coordinator packs submissions into trial batches in
//! arrival order, so by emitting equal-pixel requests back-to-back we
//! maximize the chance they land in the same batch, where the
//! trial-blocked kernel's row-grouping collapses them into one weight
//! sweep (PR-5's amortization, now reachable from HTTP regardless of the
//! order clients happened to connect in).
//!
//! Grouping never touches request identity: every request keeps its own
//! id and therefore its own trial stream (`trial_stream_base`), so the
//! merged path is bit-identical to submitting the requests one by one.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::serve::{Backend, InferResponse};
use crate::telemetry::{EventKind, Journal};

use super::server::QueuedInfer;

/// Most requests drained into one flush.  Bounds the latency a request
/// can accrue behind the grouping sweep itself; the backend's own queue
/// depth does the real pacing.
pub const MAX_FLUSH: usize = 64;

/// Flush counters for `GET /metrics`.
#[derive(Debug, Default)]
pub struct BatcherStats {
    /// Batches pushed to the backend.
    pub flushes: AtomicU64,
    /// Requests flushed in total.
    pub requests: AtomicU64,
    /// Requests that joined an earlier request's group (identical
    /// pixels) — each one is a weight sweep the kernel may now skip.
    pub merged: AtomicU64,
}

impl BatcherStats {
    pub fn counts(&self) -> (u64, u64, u64) {
        (
            self.flushes.load(Ordering::Relaxed),
            self.requests.load(Ordering::Relaxed),
            self.merged.load(Ordering::Relaxed),
        )
    }
}

/// Group indices of `images` by bit-identical content, first-occurrence
/// order — `engine::group_equal_rows` generalized to rows of possibly
/// differing length.  Same FNV-1a prefilter over the raw bits, same
/// verified equality against the group representative.
pub fn group_compatible(images: &[&[f32]]) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut hashes: Vec<u64> = Vec::new();
    'rows: for (r, row) in images.iter().enumerate() {
        let mut h = 0xcbf29ce484222325u64;
        for v in row.iter() {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        for (g, grp) in groups.iter_mut().enumerate() {
            if hashes[g] == h && images[grp[0]] == *row {
                grp.push(r);
                continue 'rows;
            }
        }
        groups.push(vec![r]);
        hashes.push(h);
    }
    groups
}

/// Spawn the batcher thread.  Exits when every queue sender is gone
/// (server and all connection handlers dropped).
pub fn spawn(
    rx: mpsc::Receiver<QueuedInfer>,
    backend: Arc<dyn Backend>,
    journal: Arc<Journal>,
    stats: Arc<BatcherStats>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("raca-http-batcher".into())
        .spawn(move || loop {
            let first = match rx.recv() {
                Ok(q) => q,
                Err(_) => return,
            };
            let mut pending = vec![first];
            while pending.len() < MAX_FLUSH {
                match rx.try_recv() {
                    Ok(q) => pending.push(q),
                    Err(_) => break,
                }
            }
            flush(pending, &backend, &journal, &stats);
        })
        .expect("spawning http batcher thread")
}

fn flush(
    batch: Vec<QueuedInfer>,
    backend: &Arc<dyn Backend>,
    journal: &Arc<Journal>,
    stats: &Arc<BatcherStats>,
) {
    let images: Vec<&[f32]> = batch.iter().map(|q| q.req.image.as_slice()).collect();
    let groups = group_compatible(&images);
    stats.flushes.fetch_add(1, Ordering::Relaxed);
    stats.requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
    stats.merged.fetch_add((batch.len() - groups.len()) as u64, Ordering::Relaxed);
    if batch.len() > 1 {
        journal.record(
            EventKind::BatchFormed,
            "http",
            format!("{} reqs -> {} groups", batch.len(), groups.len()),
        );
    }

    let mut slots: Vec<Option<QueuedInfer>> = batch.into_iter().map(Some).collect();
    for grp in groups {
        for idx in grp {
            let mut q = slots[idx].take().expect("each index appears in exactly one group");
            let id = q.req.id;
            // Queue wait counts against the deadline budget: shed here if
            // the wait already consumed it, otherwise forward only the
            // remainder so downstream stages see an honest budget.
            let waited = q.enqueued.elapsed();
            if q.req.past_deadline(waited) {
                journal.record(
                    EventKind::DeadlineExceeded,
                    "http",
                    format!("id {id}: shed in the ingress queue"),
                );
                let _ = q.reply.send(InferResponse::failed(
                    id,
                    crate::serve::deadline_exceeded_msg(
                        "http ingress",
                        waited,
                        q.req.deadline_ms.unwrap_or(0),
                    ),
                ));
                continue;
            }
            if let Some(d) = q.req.deadline_ms {
                q.req.deadline_ms = Some(d - waited.as_millis() as u64);
            }
            // An admitted request is always answered: a submit error
            // becomes an in-band failure on its reply channel (the
            // connection handler is blocked on it).
            if let Err(e) = backend.submit_to(q.req, q.reply.clone()) {
                let _ = q.reply.send(InferResponse::failed(id, format!("{e:#}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_identical_images_first_occurrence_order() {
        let a = vec![0.25f32, 0.5, 0.75];
        let b = vec![0.25f32, 0.5, 0.75 + f32::EPSILON];
        let rows: Vec<&[f32]> = vec![&a, &b, &a, &a, &b];
        assert_eq!(group_compatible(&rows), vec![vec![0, 2, 3], vec![1, 4]]);
    }

    #[test]
    fn different_lengths_never_group() {
        let a = vec![1.0f32, 2.0];
        let b = vec![1.0f32, 2.0, 0.0];
        let rows: Vec<&[f32]> = vec![&a, &b];
        assert_eq!(group_compatible(&rows), vec![vec![0], vec![1]]);
    }

    #[test]
    fn negative_zero_is_a_distinct_bit_pattern() {
        // -0.0 == 0.0 numerically but the bit patterns differ, so the
        // hash prefilter keeps them apart — the conservative direction
        // (a missed merge, never a wrong one).
        let a = vec![0.0f32];
        let b = vec![-0.0f32];
        let rows: Vec<&[f32]> = vec![&a, &b];
        assert_eq!(group_compatible(&rows), vec![vec![0], vec![1]]);
    }
}
