//! The HTTP/1.1 listener: accept loop, request framing, and the wiring
//! of admission → queue → batcher → backend.
//!
//! Deliberately minimal, in the spirit of the paper's
//! delete-the-periphery discipline: hand-rolled HTTP over std TCP — no
//! chunked bodies (`Content-Length` only, capped at the wire layer's 16
//! MiB frame limit), no TLS, no routing table beyond a four-arm match.
//! Keep-alive is the default for HTTP/1.1 peers so a load generator can
//! amortize its connection; one thread per connection, same as the
//! framed-socket listener in [`crate::serve::net::server`].
//!
//! Request lifecycle: the connection thread parses the request,
//! [`super::admission`] decides whether it may enter (429 + `Retry-After`
//! otherwise), the lazy scanner pulls `id`/`pixels`/`trials` out of the
//! body, and the request goes onto a *bounded* queue.  The
//! [`super::batcher`] thread drains that queue, merges identical-pixel
//! requests, and submits to the backend; the connection thread blocks on
//! its reply channel and writes the response.  Nothing in the path can
//! grow without bound, and every admitted request is answered — the two
//! invariants the saturation tests pin.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::Metrics;
use crate::serve::{Backend, InferRequest, InferResponse};
use crate::telemetry::{journal::DEFAULT_CAPACITY, Journal};
use crate::util::json;

use super::admission::Admission;
use super::batcher::{self, BatcherStats};
use super::routes::{self, Reply};
use super::HttpConfig;

/// Request line / header line length cap.
const MAX_LINE_BYTES: usize = 8 * 1024;

/// Header count cap.
const MAX_HEADERS: usize = 64;

/// Body cap — the same 16 MiB the framed wire layer enforces, so a
/// request that fits one ingress fits the other.
pub const MAX_BODY_BYTES: usize = json::MAX_FRAME_BYTES;

/// A connection must deliver each request (line + headers + body)
/// within this window; slow-loris peers get cut, idle keep-alive
/// connections past it are recycled.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Journal events returned by `GET /tree`, matching the wire listener.
pub(crate) const JOURNAL_TAIL: usize = 32;

/// One admitted request in flight between a connection thread and the
/// batcher.
pub struct QueuedInfer {
    pub req: InferRequest,
    pub reply: mpsc::Sender<InferResponse>,
    /// When the request entered the ingress queue — the batcher charges
    /// the queue wait against the request's deadline budget (and sheds
    /// it outright if the wait already consumed the budget).
    pub enqueued: std::time::Instant,
}

/// Shared per-listener state handed to every connection thread.
pub(crate) struct Ingress {
    pub backend: Arc<dyn Backend>,
    pub admission: Arc<Admission>,
    pub queue: mpsc::SyncSender<QueuedInfer>,
    /// The ingress's own telemetry node (admitted/completed/latency).
    pub metrics: Arc<Metrics>,
    pub stats: Arc<BatcherStats>,
    pub journal: Arc<Journal>,
    /// Telemetry label, `http:<bound-addr>`.
    pub label: String,
}

/// Handle on a running HTTP listener.  Dropping it stops the accept
/// loop; connection threads wind down as their peers disconnect.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

/// Bind `cfg.addr` and serve `backend` behind admission control.
pub fn serve_http(backend: Box<dyn Backend>, cfg: &HttpConfig) -> Result<HttpServer> {
    let backend: Arc<dyn Backend> = Arc::from(backend);
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding http ingress on {}", cfg.addr))?;
    let addr = listener.local_addr().context("resolving http ingress address")?;
    listener.set_nonblocking(true).context("setting http listener non-blocking")?;

    // Share the backend's journal when it has one so ingress events
    // interleave with backend events in one stream.
    let journal = backend.journal().unwrap_or_else(|| Journal::new(DEFAULT_CAPACITY));
    let admission = Admission::new(cfg.in_flight, cfg.tenant_rate, cfg.tenant_burst);
    let (queue_tx, queue_rx) = mpsc::sync_channel(cfg.queue_depth.max(1));
    let stats = Arc::new(BatcherStats::default());
    let _batcher = batcher::spawn(queue_rx, backend.clone(), journal.clone(), stats.clone());

    let ctx = Arc::new(Ingress {
        backend,
        admission,
        queue: queue_tx,
        metrics: Metrics::new(),
        stats,
        journal,
        label: format!("http:{addr}"),
    });

    let stop = Arc::new(AtomicBool::new(false));
    let accept = {
        let stop = stop.clone();
        thread::Builder::new()
            .name("raca-http-accept".into())
            .spawn(move || loop {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let ctx = ctx.clone();
                        let _ = thread::Builder::new()
                            .name("raca-http-conn".into())
                            .spawn(move || connection(stream, ctx));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(20));
                    }
                    Err(e) => {
                        log::warn!("http accept on {addr} failed: {e}");
                        thread::sleep(Duration::from_millis(20));
                    }
                }
            })
            .context("spawning http accept thread")?
    };

    Ok(HttpServer { addr, stop, accept: Some(accept) })
}

impl HttpServer {
    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the listener stops (i.e. forever in the CLI
    /// foreground path, until ctrl-c kills the process).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Per-connection request loop
// ---------------------------------------------------------------------------

struct RawRequest {
    method: String,
    path: String,
    keep_alive: bool,
    content_length: usize,
    tenant: Option<String>,
    /// `X-Raca-Deadline-Ms`: the caller's total latency budget.  Expired
    /// work is shed down the tree with an in-band `deadline_exceeded`
    /// failure, surfaced here as `504 Gateway Timeout`.
    deadline_ms: Option<u64>,
    expect_continue: bool,
}

fn connection(stream: TcpStream, ctx: Arc<Ingress>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let mut read = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut write = stream;

    loop {
        let raw = match read_request(&mut read) {
            Ok(Some(r)) => r,
            // Clean close between requests.
            Ok(None) => return,
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                let _ = respond(&mut write, &Reply::error(400, "Bad Request", &e.to_string()), false);
                return;
            }
            // Timeout / reset: nothing useful to say on a broken pipe.
            Err(_) => return,
        };

        if raw.content_length > MAX_BODY_BYTES {
            // Refuse before reading: we will not allocate for it, and
            // without draining the body the connection can't be reused.
            let msg = format!(
                "body of {} bytes exceeds the {MAX_BODY_BYTES}-byte cap",
                raw.content_length
            );
            let _ = respond(&mut write, &Reply::error(413, "Payload Too Large", &msg), false);
            return;
        }
        if raw.expect_continue && raw.content_length > 0 {
            // Clients like curl wait for this before sending the body.
            if write
                .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .and_then(|_| write.flush())
                .is_err()
            {
                return;
            }
        }
        let mut body = vec![0u8; raw.content_length];
        if read.read_exact(&mut body).is_err() {
            return;
        }

        let reply = routes::dispatch(
            &raw.method,
            &raw.path,
            raw.tenant.as_deref(),
            raw.deadline_ms,
            &body,
            &ctx,
        );
        if respond(&mut write, &reply, raw.keep_alive).is_err() || !raw.keep_alive {
            return;
        }
    }
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// Read one CRLF-terminated line, bounded.  `Ok(None)` on EOF before
/// any byte (clean close); `InvalidData` on oversized or truncated
/// lines.
fn read_line_bounded(r: &mut BufReader<TcpStream>) -> io::Result<Option<String>> {
    let mut buf = Vec::new();
    let n = r.by_ref().take(MAX_LINE_BYTES as u64 + 1).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if buf.len() > MAX_LINE_BYTES {
        return Err(bad("header line too long"));
    }
    if buf.last() != Some(&b'\n') {
        return Err(bad("connection closed mid-line"));
    }
    while matches!(buf.last(), Some(b'\n' | b'\r')) {
        buf.pop();
    }
    String::from_utf8(buf).map(Some).map_err(|_| bad("header line is not UTF-8"))
}

fn read_request(r: &mut BufReader<TcpStream>) -> io::Result<Option<RawRequest>> {
    // Tolerate one stray CRLF before the request line (RFC 9112 §2.2).
    let mut line = match read_line_bounded(r)? {
        Some(l) => l,
        None => return Ok(None),
    };
    if line.is_empty() {
        line = match read_line_bounded(r)? {
            Some(l) => l,
            None => return Ok(None),
        };
    }

    let mut parts = line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if v.starts_with("HTTP/1.") => (m, p, v),
        _ => return Err(bad("malformed request line")),
    };
    let mut req = RawRequest {
        method: method.to_string(),
        path: path.to_string(),
        // HTTP/1.1 defaults to keep-alive, 1.0 to close.
        keep_alive: version != "HTTP/1.0",
        content_length: 0,
        tenant: None,
        deadline_ms: None,
        expect_continue: false,
    };

    for n in 0.. {
        if n > MAX_HEADERS {
            return Err(bad("too many headers"));
        }
        let h = read_line_bounded(r)?.ok_or_else(|| bad("connection closed inside headers"))?;
        if h.is_empty() {
            break;
        }
        let (name, value) = h.split_once(':').ok_or_else(|| bad("malformed header"))?;
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                req.content_length =
                    value.parse().map_err(|_| bad("content-length is not an integer"))?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.split(',').any(|t| t.trim() == "close") {
                    req.keep_alive = false;
                } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                    req.keep_alive = true;
                }
            }
            "x-raca-tenant" => req.tenant = Some(value.to_string()),
            "x-raca-deadline-ms" => {
                req.deadline_ms = Some(
                    value
                        .parse()
                        .map_err(|_| bad("x-raca-deadline-ms is not an integer"))?,
                );
            }
            "expect" => req.expect_continue = value.eq_ignore_ascii_case("100-continue"),
            "transfer-encoding" => {
                return Err(bad("transfer-encoding is not supported; send content-length"));
            }
            _ => {}
        }
    }
    Ok(Some(req))
}

fn respond(w: &mut TcpStream, reply: &Reply, keep: bool) -> io::Result<()> {
    use std::fmt::Write as _;
    let mut head = String::with_capacity(160);
    let _ = write!(
        head,
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reply.status,
        reply.reason,
        reply.body.len(),
        if keep { "keep-alive" } else { "close" },
    );
    for (k, v) in &reply.headers {
        let _ = write!(head, "{k}: {v}\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(reply.body.as_bytes())?;
    w.flush()
}
