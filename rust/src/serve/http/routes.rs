//! Route dispatch for the HTTP ingress.
//!
//! Four routes, one match:
//!
//! - `POST /v1/infer` — admission-gated inference (see below).
//! - `GET /metrics`   — ingress counters + flat backend snapshot.
//! - `GET /tree`      — the PR-6 recursive metrics tree with the ingress
//!   as root, plus the journal tail — the same shape `raca top` reads
//!   off a framed socket, as plain JSON.
//! - `GET /healthz`   — liveness, nothing else.
//!
//! The infer path runs admission *before* parsing the body (a shed
//! request costs a header scan, not a 784-float parse), holds its
//! in-flight [`super::admission::Permit`] until the response is written,
//! and keeps determinism by pinning `confidence` to 0: a fixed trial
//! budget means votes depend only on `(seed, id, trial_idx)`, so an HTTP
//! reply is bit-identical to a local `die` answering the same request.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::serve::InferRequest;
use crate::telemetry::{tree::snapshot_to_json, EventKind, MetricsTree};
use crate::util::json::{self, Json, LazyObject};

use super::server::{Ingress, QueuedInfer, JOURNAL_TAIL};

/// Trial budget when the body omits `"trials"`.
const DEFAULT_TRIALS: u64 = 32;

/// Hard per-request trial cap: admission control for compute, not just
/// queue slots — one request must not monopolize the fabric.
const MAX_TRIALS: u64 = 1 << 20;

/// A response ready for the socket.
pub struct Reply {
    pub status: u16,
    pub reason: &'static str,
    pub headers: Vec<(&'static str, String)>,
    pub body: String,
}

impl Reply {
    pub fn json(status: u16, reason: &'static str, body: Json) -> Self {
        Reply { status, reason, headers: Vec::new(), body: body.to_string() }
    }

    pub fn error(status: u16, reason: &'static str, msg: &str) -> Self {
        Reply::json(status, reason, json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    fn shed(retry_after_secs: u64, reason: &str) -> Self {
        let mut r = Reply::json(
            429,
            "Too Many Requests",
            json::obj(vec![
                ("error", Json::Str(format!("shed: {reason}"))),
                ("retry_after", json::num(retry_after_secs as f64)),
            ]),
        );
        r.headers.push(("Retry-After", retry_after_secs.to_string()));
        r
    }
}

pub(crate) fn dispatch(
    method: &str,
    path: &str,
    tenant: Option<&str>,
    deadline_ms: Option<u64>,
    body: &[u8],
    ctx: &Arc<Ingress>,
) -> Reply {
    match (method, path) {
        ("POST", "/v1/infer") => infer(tenant, deadline_ms, body, ctx),
        ("GET", "/metrics") => metrics(ctx),
        ("GET", "/tree") => tree(ctx),
        ("GET", "/healthz") => Reply::json(200, "OK", json::obj(vec![("ok", Json::Bool(true))])),
        (_, "/v1/infer") | (_, "/metrics") | (_, "/tree") | (_, "/healthz") => {
            let allow = if path == "/v1/infer" { "POST" } else { "GET" };
            let mut r = Reply::error(405, "Method Not Allowed", "method not allowed");
            r.headers.push(("Allow", allow.to_string()));
            r
        }
        _ => Reply::error(404, "Not Found", &format!("no route for {path}")),
    }
}

fn infer(
    tenant: Option<&str>,
    deadline_ms: Option<u64>,
    body: &[u8],
    ctx: &Arc<Ingress>,
) -> Reply {
    use super::admission::Verdict;

    let t0 = Instant::now();
    let permit = match ctx.admission.try_admit(tenant) {
        Verdict::Admitted(p) => p,
        Verdict::Shed { retry_after_secs, reason } => {
            ctx.journal.record(EventKind::IngressShed, &ctx.label, reason);
            return Reply::shed(retry_after_secs, reason);
        }
    };

    // Lazy extraction: only the three fields we need, straight off the
    // body bytes (ADR-002 style — no tree for the pixel array).
    let doc = LazyObject::new(body);
    let id = match doc.u64_field("id") {
        Ok(Some(v)) => v,
        Ok(None) => return Reply::error(400, "Bad Request", "missing 'id' (request id)"),
        Err(e) => return Reply::error(400, "Bad Request", &format!("bad body: {e}")),
    };
    let pixels = match doc.f32_array("pixels") {
        Ok(Some(p)) if !p.is_empty() => p,
        Ok(Some(_)) => return Reply::error(400, "Bad Request", "'pixels' must be non-empty"),
        Ok(None) => return Reply::error(400, "Bad Request", "missing 'pixels' (input image)"),
        Err(e) => return Reply::error(400, "Bad Request", &format!("bad body: {e}")),
    };
    let trials = match doc.u64_field("trials") {
        Ok(Some(t)) if (1..=MAX_TRIALS).contains(&t) => t,
        Ok(None) => DEFAULT_TRIALS,
        Ok(Some(t)) => {
            return Reply::error(
                400,
                "Bad Request",
                &format!("'trials' must be in 1..={MAX_TRIALS}, got {t}"),
            )
        }
        Err(e) => return Reply::error(400, "Bad Request", &format!("bad body: {e}")),
    };

    // confidence 0 → fixed budget; the client id keys the trial streams
    // (same contract as the framed wire), so duplicate in-flight ids are
    // the client's in-band failure to own.
    let mut req = InferRequest::new(id, pixels).with_budget(trials as u32, 0.0);
    if let Some(d) = deadline_ms {
        req = req.with_deadline_ms(d);
    }
    let (tx, rx) = mpsc::channel();
    match ctx.queue.try_send(QueuedInfer { req, reply: tx, enqueued: Instant::now() }) {
        Ok(()) => {}
        Err(mpsc::TrySendError::Full(_)) => {
            ctx.admission.note_shed_queue();
            ctx.journal.record(EventKind::IngressShed, &ctx.label, "queue full");
            drop(permit);
            return Reply::shed(1, "queue full");
        }
        Err(mpsc::TrySendError::Disconnected(_)) => {
            drop(permit);
            return Reply::error(503, "Service Unavailable", "ingress batcher is gone");
        }
    }
    ctx.metrics.requests_admitted.fetch_add(1, Ordering::Relaxed);
    ctx.journal.record(EventKind::RequestAdmitted, &ctx.label, format!("id {id}"));

    // The batcher either submits the request or answers in-band, and the
    // backend answers every submission, so this resolves — the permit
    // (and with it the in-flight slot) is held until then.
    let resp = match rx.recv() {
        Ok(r) => r,
        Err(_) => {
            drop(permit);
            return Reply::error(500, "Internal Server Error", "reply channel closed");
        }
    };
    drop(permit);

    if let Some(err) = resp.error {
        ctx.metrics.engine_errors.fetch_add(1, Ordering::Relaxed);
        ctx.journal.record(EventKind::RequestFailed, &ctx.label, format!("id {id}: {err}"));
        // A shed deadline is the caller's timeout, not our fault: 504,
        // so clients can tell "too slow" from "broken" without parsing
        // the message (the prefix contract from `serve::request`).
        let (status, reason) = if err.starts_with(crate::serve::DEADLINE_EXCEEDED) {
            (504, "Gateway Timeout")
        } else {
            (500, "Internal Server Error")
        };
        return Reply::json(
            status,
            reason,
            json::obj(vec![("id", Json::Str(id.to_string())), ("error", Json::Str(err))]),
        );
    }
    ctx.metrics.requests_completed.fetch_add(1, Ordering::Relaxed);
    ctx.metrics.trials_executed.fetch_add(resp.trials_used as u64, Ordering::Relaxed);
    ctx.metrics.record_latency(t0.elapsed());
    ctx.journal.record(
        EventKind::RequestCompleted,
        &ctx.label,
        format!("id {id}, {} trials", resp.trials_used),
    );

    Reply::json(
        200,
        "OK",
        json::obj(vec![
            // Ids travel as decimal strings, like the framed wire.
            ("id", Json::Str(resp.id.to_string())),
            ("prediction", json::num(resp.prediction as f64)),
            (
                "counts",
                Json::Arr(resp.outcome.counts.iter().map(|&c| json::num(c as f64)).collect()),
            ),
            ("abstentions", json::num(resp.outcome.abstentions as f64)),
            ("trials", json::num(resp.outcome.trials as f64)),
            ("trials_used", json::num(resp.trials_used as f64)),
            ("latency_us", json::num(resp.latency.as_micros() as f64)),
        ]),
    )
}

fn metrics(ctx: &Arc<Ingress>) -> Reply {
    let adm = ctx.admission.stats();
    let (flushes, flushed, merged) = ctx.stats.counts();
    Reply::json(
        200,
        "OK",
        json::obj(vec![
            (
                "ingress",
                json::obj(vec![
                    ("admitted", json::num(adm.admitted as f64)),
                    ("shed_queue", json::num(adm.shed_queue as f64)),
                    ("shed_in_flight", json::num(adm.shed_in_flight as f64)),
                    ("shed_rate", json::num(adm.shed_rate as f64)),
                    ("shed_total", json::num(adm.shed_total() as f64)),
                    ("in_flight_now", json::num(adm.in_flight_now as f64)),
                    ("batch_flushes", json::num(flushes as f64)),
                    ("batch_requests", json::num(flushed as f64)),
                    ("batch_merged", json::num(merged as f64)),
                    ("snapshot", snapshot_to_json(&ctx.metrics.snapshot())),
                ]),
            ),
            ("backend", snapshot_to_json(&ctx.backend.metrics())),
        ]),
    )
}

fn tree(ctx: &Arc<Ingress>) -> Reply {
    let root = MetricsTree::leaf(ctx.label.clone(), ctx.metrics.snapshot())
        .with_children(vec![ctx.backend.metrics_tree()]);
    let events: Vec<Json> =
        ctx.journal.tail(JOURNAL_TAIL).iter().map(|e| e.to_json()).collect();
    Reply::json(
        200,
        "OK",
        json::obj(vec![("tree", root.to_json()), ("events", Json::Arr(events))]),
    )
}
