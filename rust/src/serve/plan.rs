//! Deployment topologies: a recursive tree of serving shapes, compiled
//! into nested [`Backend`]s.
//!
//! The paper's architecture is "flexibly configured" at the layer/spec
//! level (§III-C); this module applies the same flexibility to how dies
//! compose into a *service*.  Replication and pipelining are orthogonal
//! axes (Marinella et al.'s multiscale co-design; the tiled/pipelined
//! organizations in Smagulova et al.'s survey), so instead of a flat
//! backend switch the deployment is a [`Topology`] tree:
//!
//! * [`Topology::Die`] — leaf: one chip (native, physical, or — under the
//!   `pjrt` feature — an AOT/XLA die);
//! * [`Topology::Pipeline`] — leaf: one model sharded layer-ranges-per-die
//!   across N chips ([`crate::arch::ShardPlan`]), activations streamed
//!   die-to-die;
//! * [`Topology::Remote`] — leaf: a peer host's `raca serve --listen`
//!   socket ([`crate::serve::net::RemoteBackend`]) — the tree crosses
//!   process and machine boundaries here;
//! * [`Topology::Replicate`] — combinator: N copies of any subtree behind
//!   a health-reweighted [`Router`];
//! * [`Topology::Group`] — combinator: *distinct* subtrees behind the
//!   same router — the multi-host shape `(remote:a, remote:b)` that
//!   health-steers across machines with zero new routing code.
//!
//! [`DeployPlan::compile`] walks the tree and numbers every physical die
//! once (fleet-wide chip ids ⇒ distinct variation draws per replica);
//! [`build`] turns the plan into a `Box<dyn Backend>`: replicate-over-die
//! fuses into the per-chip worker [`ReplicatedFleetBackend`], every other
//! replicate becomes a [`RouterBackend`] over recursively built children,
//! so health reweighting and eviction work at *any* level of the tree.
//!
//! **Parity discipline:** every leaf derives per-request trial indices
//! from `trial_stream_base(seed, request id)`.  Pipeline leaves (and a
//! bare `die` root) additionally draw trial noise from the deployment
//! seed itself, so with `variation: None` their votes are bit-identical
//! to the unsharded [`crate::engine::NativeEngine`] at equal
//! `(seed, trial_idx)` — regardless of where the pipeline sits in the
//! tree (`rust/tests/serve.rs` holds `2x(pipeline:3)` to that).  Fused
//! `<n>x(die)` worker fleets keep the flat-fleet semantics instead:
//! each die serves with its private `chip_seed` RNG identity, so their
//! responses are reproducible per fixed tree and routing, not
//! shape-independent ([`crate::serve::ReplicatedFleetBackend`] docs).
//!
//! # Spec grammar (case-insensitive)
//!
//! ```text
//! node   := '(' node { ',' node } ')' [ '@' policy ]
//!                                               1 node: plain grouping;
//!                                               2+: route across the
//!                                               listed (distinct) children
//!         | COUNT 'x' node [ '@' policy ]       N replicas of node
//!         | 'die' [ ':' engine ]                engine: native|physical|pjrt
//!         | 'pipeline' ':' COUNT [ ':b' COUNT ] COUNT dies; :bN = trials per
//!                                               die-to-die message
//!         | 'remote' ':' ADDR                   ADDR = host:port of a peer's
//!                                               `raca serve --listen` socket
//!         | 'remote' ':@' ADDR '/' BUNDLE       registry-resolved leaf: the
//!                                               listener at ADDR must
//!                                               advertise BUNDLE (a 64-hex
//!                                               bundle id), whose signed
//!                                               manifest is verified under
//!                                               the local deployment key at
//!                                               build time
//! policy := round-robin|rr | least-loaded|ll | weighted|wt
//! ```
//!
//! Examples: `die`, `8x(die)@weighted`, `pipeline:3`, `2x(pipeline:3)`,
//! `pipeline:4:b16`, `2x(2x(die))`, `remote:10.0.0.7:7433`,
//! `(remote:a:7433, remote:b:7433)@weighted`, `(pipeline:3, remote:b:7433)`,
//! `remote:@10.0.0.7:7433/3b4f…e1` (case folding is harmless: bundle ids
//! are lowercase hex by construction).
//! `raca serve --topology "<spec>"` and the `"serve": {"topology":
//! "<spec>"}` config key accept this grammar; the legacy `BackendKind`
//! spellings are parse-only sugar that map onto canonical trees
//! ([`super::BackendKind::to_topology`]).
//!
//! A `remote:` leaf contributes **no local dies**: its chips are
//! numbered, programmed and seeded by the host that serves it, which is
//! also where its bit-parity seed lives — seed the listener and a local
//! reference alike and `remote:die` votes bit-identically to `die`
//! (`rust/tests/serve.rs`).

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::arch::ShardPlan;
use crate::coordinator::{Metrics, MetricsSnapshot, SchedulerConfig, TrialRunner};
use crate::dataset::Dataset;
use crate::device::VariationModel;
use crate::engine::{NativeEngine, TrialEngine, TrialParams};
use crate::fleet::{
    chip_seed, program_weights, Calibrator, Chip, ChipId, Fleet, HealthConfig, HealthMonitor,
    RoutePolicy, Router,
};
use crate::nn::{ModelSpec, Weights};
use crate::stats::GaussianSource;
use crate::telemetry::{journal::DEFAULT_CAPACITY, EventKind, Journal, MetricsTree};

use super::net::RemoteBackend;
use super::probe::ProbeInjector;
use super::{
    Backend, InferRequest, InferResponse, PipelineOptions, PipelinedFleetBackend,
    ReplicatedFleetBackend, ReplicatedOptions, RequestId, SingleChipBackend,
    DEADLINE_EXCEEDED,
};

/// Crossbar tile edge used for shard balancing (the repo-wide default).
const TILE: usize = 128;

/// Which engine a [`Topology::Die`] leaf runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineSel {
    #[default]
    Native,
    /// Full analog simulation (validation-grade, slow).
    Physical,
    /// AOT/XLA over PJRT (requires the `pjrt` feature + artifacts).
    Pjrt,
}

impl EngineSel {
    pub fn name(&self) -> &'static str {
        match self {
            EngineSel::Native => "native",
            EngineSel::Physical => "physical",
            EngineSel::Pjrt => "pjrt",
        }
    }
}

/// A deployment shape: how simulated RACA dies compose into one service.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Topology {
    /// One chip behind the batched scheduler.
    Die { engine: EngineSel },
    /// One model sharded layer-ranges-per-die across `shards` chips.
    /// `batch` pins the trials-per-message block size (`None` = the
    /// deployment default, [`BuildOptions::batch`]).
    Pipeline { shards: usize, batch: Option<usize> },
    /// A peer host's `raca serve --listen` socket: whatever topology that
    /// listener hosts, reached over the [`crate::serve::net`] wire.  An
    /// `@<host:port>/<bundle>` address additionally pins *what* the peer
    /// serves: [`build`] resolves the bundle through the registry
    /// (advertisement check, signature verification under the local
    /// deployment key) before connecting.
    Remote { addr: String },
    /// `n` copies of `child` behind a health-reweighted router.
    Replicate { n: usize, policy: RoutePolicy, child: Box<Topology> },
    /// Distinct children behind one health-reweighted router — the
    /// heterogeneous/multi-host combinator (`(remote:a, remote:b)`,
    /// `(pipeline:3, remote:b:7433)`).
    Group { policy: RoutePolicy, children: Vec<Topology> },
}

impl Topology {
    /// Parse a topology spec (case-insensitive; grammar in module docs).
    pub fn parse(spec: &str) -> Result<Self> {
        let lower = spec.trim().to_ascii_lowercase();
        let (node, rest) =
            parse_node(&lower).map_err(|e| anyhow!("topology '{spec}': {e}"))?;
        let rest = rest.trim();
        if !rest.is_empty() {
            bail!("topology '{spec}': trailing input '{rest}'");
        }
        node.validate().map_err(|e| anyhow!("topology '{spec}': {e}"))?;
        Ok(node)
    }

    /// Structural validation (also applied by [`Topology::parse`] and at
    /// config-validation time): zero-sized nodes are rejected like the
    /// existing zero-sized fleet checks.
    pub fn validate(&self) -> std::result::Result<(), String> {
        match self {
            Topology::Die { .. } => Ok(()),
            Topology::Pipeline { shards, batch } => {
                if *shards == 0 {
                    return Err("a pipeline needs at least one die (got pipeline:0)".into());
                }
                if *batch == Some(0) {
                    return Err("a pipeline trial batch must be at least 1 (got :b0)".into());
                }
                Ok(())
            }
            Topology::Remote { addr } => {
                // Registry-resolved form: `@<host:port>/<bundle-id>`.
                if let Some(spec) = addr.strip_prefix('@') {
                    let (host_port, bundle) = spec.split_once('/').ok_or_else(|| {
                        format!("remote:{addr}: expected remote:@<host:port>/<bundle-id>")
                    })?;
                    let (host, port) = host_port.rsplit_once(':').ok_or_else(|| {
                        format!("remote:{addr}: expected remote:@<host:port>/<bundle-id>")
                    })?;
                    if host.is_empty() || port.is_empty() {
                        return Err(format!(
                            "remote:{addr}: expected remote:@<host:port>/<bundle-id>"
                        ));
                    }
                    if !crate::registry::sign::is_digest(bundle) {
                        return Err(format!(
                            "remote:{addr}: '{bundle}' is not a bundle id \
                             (64 lowercase hex chars; see `raca bundles`)"
                        ));
                    }
                    return Ok(());
                }
                let (host, port) = addr
                    .rsplit_once(':')
                    .ok_or_else(|| format!("remote:{addr}: expected remote:<host:port>"))?;
                if host.is_empty() || port.is_empty() {
                    return Err(format!("remote:{addr}: expected remote:<host:port>"));
                }
                Ok(())
            }
            Topology::Replicate { n, child, .. } => {
                if *n == 0 {
                    return Err(
                        "a replication factor must be at least 1 (got 0x(…))".into()
                    );
                }
                child.validate()
            }
            Topology::Group { children, .. } => {
                if children.is_empty() {
                    return Err("a group needs at least one child".into());
                }
                children.iter().try_for_each(Topology::validate)
            }
        }
    }

    /// Total *local* physical dies this tree deploys.  A `remote:` leaf
    /// contributes zero: its dies are owned (numbered, programmed,
    /// seeded) by the host serving it.
    pub fn dies(&self) -> usize {
        match self {
            Topology::Die { .. } => 1,
            Topology::Pipeline { shards, .. } => *shards,
            Topology::Remote { .. } => 0,
            Topology::Replicate { n, child, .. } => n * child.dies(),
            Topology::Group { children, .. } => children.iter().map(Topology::dies).sum(),
        }
    }
}

impl fmt::Display for Topology {
    /// Canonical spec spelling; `Topology::parse(t.to_string()) == t`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Topology::Die { engine: EngineSel::Native } => write!(f, "die"),
            Topology::Die { engine } => write!(f, "die:{}", engine.name()),
            Topology::Pipeline { shards, batch: None } => write!(f, "pipeline:{shards}"),
            Topology::Pipeline { shards, batch: Some(b) } => {
                write!(f, "pipeline:{shards}:b{b}")
            }
            Topology::Remote { addr } => write!(f, "remote:{addr}"),
            Topology::Replicate { n, policy, child } => {
                write!(f, "{n}x({child})")?;
                if *policy != RoutePolicy::default() {
                    write!(f, "@{}", policy.name())?;
                }
                Ok(())
            }
            Topology::Group { policy, children } => {
                write!(f, "(")?;
                for (i, c) in children.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{c}")?;
                }
                write!(f, ")")?;
                if *policy != RoutePolicy::default() {
                    write!(f, "@{}", policy.name())?;
                }
                Ok(())
            }
        }
    }
}

/// Leading decimal digits of `s`, split off.
fn split_digits(s: &str) -> (&str, &str) {
    let end = s.find(|c: char| !c.is_ascii_digit()).unwrap_or(s.len());
    s.split_at(end)
}

/// Optional `@policy` suffix; returns (policy, remainder).  Terminated by
/// anything that can follow a node: `)`, `,`, whitespace, or the end.
fn parse_policy_suffix(s: &str) -> std::result::Result<(RoutePolicy, &str), String> {
    let Some(p) = s.strip_prefix('@') else {
        return Ok((RoutePolicy::default(), s));
    };
    let end = p
        .find(|c: char| c == ')' || c == ',' || c.is_whitespace())
        .unwrap_or(p.len());
    let policy = RoutePolicy::parse(&p[..end]).ok_or_else(|| {
        format!(
            "unknown route policy '{}' (valid: {})",
            &p[..end],
            RoutePolicy::SPELLINGS
        )
    })?;
    Ok((policy, &p[end..]))
}

/// Recursive-descent parser over a lower-cased spec; returns the node and
/// the unconsumed remainder.
fn parse_node(s: &str) -> std::result::Result<(Topology, &str), String> {
    let s = s.trim_start();
    // Parenthesized node, or — with commas — a group of distinct children
    // routed like replicas: `(remote:a:1, remote:b:1)@weighted`.
    if let Some(inner) = s.strip_prefix('(') {
        let (first, rest) = parse_node(inner)?;
        let mut children = vec![first];
        let mut rest = rest.trim_start();
        while let Some(r) = rest.strip_prefix(',') {
            let (node, r) = parse_node(r)?;
            children.push(node);
            rest = r.trim_start();
        }
        let rest = rest
            .strip_prefix(')')
            .ok_or_else(|| format!("missing ')' after '{}'", children.last().unwrap()))?;
        if children.len() == 1 {
            // Plain grouping parens: transparent, no policy of their own.
            return Ok((children.pop().unwrap(), rest));
        }
        let (policy, rest) = parse_policy_suffix(rest.trim_start())?;
        return Ok((Topology::Group { policy, children }, rest));
    }
    // Replicate: `<n>x<node>[@policy]`.
    let (digits, after) = split_digits(s);
    if !digits.is_empty() && after.starts_with('x') {
        let n: usize = digits
            .parse()
            .map_err(|_| format!("bad replica count '{digits}'"))?;
        let (child, rest) = parse_node(&after[1..])?;
        let (policy, rest) = parse_policy_suffix(rest.trim_start())?;
        return Ok((Topology::Replicate { n, policy, child: Box::new(child) }, rest));
    }
    // Remote leaf: `remote:<host:port>` — the address runs to the next
    // structural character (`,`, `)`, whitespace) or the end of input.
    if let Some(rest) = s.strip_prefix("remote") {
        let rest = rest.strip_prefix(':').ok_or_else(|| {
            "remote needs an address: remote:<host:port>".to_string()
        })?;
        let end = rest
            .find(|c: char| c == ')' || c == ',' || c.is_whitespace())
            .unwrap_or(rest.len());
        let addr = &rest[..end];
        if addr.is_empty() {
            return Err("remote needs an address: remote:<host:port>".into());
        }
        return Ok((Topology::Remote { addr: addr.to_string() }, &rest[end..]));
    }
    // Pipeline leaf: `pipeline:<dies>[:b<batch>]`.
    if let Some(rest) = s.strip_prefix("pipeline") {
        let rest = rest.strip_prefix(':').ok_or_else(|| {
            "pipeline needs a die count: pipeline:<dies>[:b<batch>]".to_string()
        })?;
        let (digits, mut rest) = split_digits(rest);
        if digits.is_empty() {
            return Err("pipeline needs a die count: pipeline:<dies>[:b<batch>]".into());
        }
        let shards: usize = digits
            .parse()
            .map_err(|_| format!("bad pipeline die count '{digits}'"))?;
        let mut batch = None;
        if let Some(b) = rest.strip_prefix(":b") {
            let (digits, after) = split_digits(b);
            if digits.is_empty() {
                return Err("pipeline batch needs a count: pipeline:<dies>:b<batch>".into());
            }
            batch = Some(
                digits
                    .parse()
                    .map_err(|_| format!("bad pipeline batch '{digits}'"))?,
            );
            rest = after;
        }
        return Ok((Topology::Pipeline { shards, batch }, rest));
    }
    // Die leaf: `die[:engine]`.
    if let Some(mut rest) = s.strip_prefix("die") {
        let mut engine = EngineSel::Native;
        if let Some(e) = rest.strip_prefix(':') {
            let end = e
                .find(|c: char| !c.is_ascii_alphanumeric())
                .unwrap_or(e.len());
            engine = match &e[..end] {
                "native" => EngineSel::Native,
                "physical" => EngineSel::Physical,
                "pjrt" | "xla" => EngineSel::Pjrt,
                other => {
                    return Err(format!(
                        "unknown die engine '{other}' (valid: native, physical, pjrt)"
                    ))
                }
            };
            rest = &e[end..];
        }
        return Ok((Topology::Die { engine }, rest));
    }
    Err(format!(
        "expected a topology node at '{s}' — valid: die[:native|physical|pjrt], \
         pipeline:<dies>[:b<batch>], remote:<host:port>, <n>x(<node>)[@policy], \
         (<node>, <node>, …)[@policy]"
    ))
}

/// Compiled topology: the tree with every physical die numbered once.
///
/// Chip ids are allocated depth-first, so a replica group's dies are a
/// contiguous span and two replicas of the same subtree never share an
/// id — which is what keys distinct per-die variation draws while the
/// *trial* streams stay the deployment seed (the parity discipline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanNode {
    Die { engine: EngineSel, chip: ChipId },
    Pipeline { shards: usize, batch: Option<usize>, chip_base: ChipId },
    /// A peer listener: consumes no local chip ids (the remote host
    /// numbers and seeds its own dies).
    Remote { addr: String },
    Replicate { policy: RoutePolicy, children: Vec<PlanNode> },
    /// Distinct children behind one router (the multi-host combinator).
    Group { policy: RoutePolicy, children: Vec<PlanNode> },
}

/// `Topology -> DeployPlan -> Box<dyn Backend>`, step one.
#[derive(Debug, Clone)]
pub struct DeployPlan {
    pub root: PlanNode,
    /// Total physical dies across the tree.
    pub total_dies: usize,
}

impl DeployPlan {
    /// Validate the tree and allocate fleet-wide chip ids.
    pub fn compile(topo: &Topology) -> Result<Self> {
        topo.validate().map_err(|e| anyhow!("invalid topology: {e}"))?;
        let mut next = 0usize;
        let root = alloc(topo, &mut next);
        Ok(Self { root, total_dies: next })
    }

    /// Human-readable tree, with per-pipeline shard detail for `spec`.
    pub fn describe(&self, spec: &ModelSpec) -> String {
        let mut out = String::new();
        render(&self.root, spec, 0, &mut out);
        out
    }
}

fn alloc(t: &Topology, next: &mut usize) -> PlanNode {
    match t {
        Topology::Die { engine } => {
            let chip = *next;
            *next += 1;
            PlanNode::Die { engine: *engine, chip }
        }
        Topology::Pipeline { shards, batch } => {
            let chip_base = *next;
            *next += shards;
            PlanNode::Pipeline { shards: *shards, batch: *batch, chip_base }
        }
        Topology::Remote { addr } => PlanNode::Remote { addr: addr.clone() },
        Topology::Replicate { n, policy, child } => PlanNode::Replicate {
            policy: *policy,
            children: (0..*n).map(|_| alloc(child, next)).collect(),
        },
        Topology::Group { policy, children } => PlanNode::Group {
            policy: *policy,
            children: children.iter().map(|c| alloc(c, next)).collect(),
        },
    }
}

fn render(node: &PlanNode, spec: &ModelSpec, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    match node {
        PlanNode::Die { engine, chip } => {
            out.push_str(&format!("{pad}die [chip {chip}] ({})\n", engine.name()));
        }
        PlanNode::Pipeline { shards, batch, chip_base } => {
            let detail = match ShardPlan::balanced(spec, TILE, *shards) {
                Ok(p) => format!(
                    "layer ranges {:?}, tiles/die {:?}",
                    p.ranges, p.tiles_per_die
                ),
                Err(e) => format!("unplannable for this model: {e}"),
            };
            let b = batch.map(|b| format!(", batch {b}")).unwrap_or_default();
            out.push_str(&format!(
                "{pad}pipeline × {shards} dies [chips {chip_base}..{}]{b} — {detail}\n",
                chip_base + shards
            ));
        }
        PlanNode::Remote { addr } => {
            out.push_str(&format!(
                "{pad}remote {addr} (wire protocol v{}, peer-owned dies)\n",
                crate::serve::net::PROTOCOL_VERSION
            ));
        }
        PlanNode::Replicate { policy, children } => {
            out.push_str(&format!(
                "{pad}replicate × {} ({})\n",
                children.len(),
                policy.name()
            ));
            for c in children {
                render(c, spec, indent + 1, out);
            }
        }
        PlanNode::Group { policy, children } => {
            out.push_str(&format!(
                "{pad}group × {} ({})\n",
                children.len(),
                policy.name()
            ));
            for c in children {
                render(c, spec, indent + 1, out);
            }
        }
    }
}

/// Everything the compiler needs besides the tree and the weights.
#[derive(Clone)]
pub struct BuildOptions {
    /// Deployment seed: the shared trial-stream identity of every leaf
    /// *and* the root of per-die variation/programming draws.
    pub seed: u64,
    /// Trial physics (σ_z, θ, WTA steps), shared by every die.
    pub trial: TrialParams,
    /// Scheduler knobs for die leaves (batch size, min_trials,
    /// max_in_flight); `params`/`seed` are overwritten from this struct.
    pub scheduler: SchedulerConfig,
    /// Per-die programming variation; `None` programs exact nominal
    /// weights (the bit-parity configuration).
    pub variation: Option<VariationModel>,
    /// Pipeline flow-control window (trials in flight per pipeline).
    pub depth: usize,
    /// Default trials per die-to-die message for pipeline leaves that
    /// don't pin their own `:bN`.
    pub batch: usize,
    /// Trials per blocked-kernel pass on every native die (the
    /// `serve.trial_block` knob; ≥ 1).  Purely a performance parameter —
    /// votes are bit-identical at any value.  Pipeline leaves block per
    /// die-to-die message instead (`batch` / `:bN`).
    pub trial_block: usize,
    /// Held-out set + calibrator: fused replica fleets calibrate against
    /// it up front (when variation is on) and recalibrate drifting dies
    /// live.  Also the image source for injected health probes.
    pub calibration: Option<(Dataset, Calibrator)>,
    /// Health steering cadence (completions between reweigh passes).
    pub reweigh_every: u64,
    /// Labeled health probes per caller request, in [0, 1] (0 disables).
    /// Applied at every routing level (fused fleets and routers alike),
    /// drawing from `calibration`'s held-out set.
    pub probe_rate: f64,
    /// Event journal shared by every node of the deployment tree
    /// (admissions, failures, probe verdicts, health steering).  `None`
    /// lets [`build`] allocate a fresh default-capacity ring.
    pub journal: Option<Arc<Journal>>,
    /// Artifact directory for artifact-consuming leaves: `die:pjrt`
    /// executables and the deployment signing key that `remote:@` leaves
    /// verify manifests under.  `None` falls back to
    /// [`crate::runtime::default_artifact_dir`].
    pub artifact_dir: Option<std::path::PathBuf>,
}

impl Default for BuildOptions {
    fn default() -> Self {
        Self {
            seed: 0x5EB0E,
            trial: TrialParams::default(),
            scheduler: SchedulerConfig::default(),
            variation: None,
            depth: 256,
            batch: 8,
            trial_block: crate::engine::DEFAULT_TRIAL_BLOCK,
            calibration: None,
            reweigh_every: 32,
            probe_rate: 0.0,
            journal: None,
            artifact_dir: None,
        }
    }
}

/// Compile `topo` and build the deployment over `nominal` weights — the
/// one entry point every serving caller goes through (`raca serve`,
/// benches, tests).
pub fn build(topo: &Topology, nominal: &Weights, opts: &BuildOptions) -> Result<Box<dyn Backend>> {
    let plan = DeployPlan::compile(topo)?;
    let journal =
        opts.journal.clone().unwrap_or_else(|| Journal::new(DEFAULT_CAPACITY));
    build_node(&plan.root, nominal, opts, &journal)
}

/// Probe source for a router level: the held-out calibration slice.
fn probe_injector(opts: &BuildOptions) -> Option<ProbeInjector> {
    let (ds, _) = opts.calibration.as_ref()?;
    ProbeInjector::new(ds.clone(), opts.probe_rate)
}

/// Telemetry label of a plan node — what the node is called in the
/// [`MetricsTree`] and in journal events (`die#3`, `pipeline:2 [chips
/// 2..4]`, `remote:host:port`, `replicate ×2 (weighted)`).
pub fn node_label(node: &PlanNode) -> String {
    match node {
        PlanNode::Die { chip, .. } => format!("die#{chip}"),
        PlanNode::Pipeline { shards, chip_base, .. } => {
            format!("pipeline:{shards} [chips {chip_base}..{}]", chip_base + shards)
        }
        PlanNode::Remote { addr } => format!("remote:{addr}"),
        PlanNode::Replicate { policy, children } => {
            format!("replicate ×{} ({})", children.len(), policy.name())
        }
        PlanNode::Group { policy, children } => {
            format!("group ×{} ({})", children.len(), policy.name())
        }
    }
}

fn build_node(
    node: &PlanNode,
    nominal: &Weights,
    opts: &BuildOptions,
    journal: &Arc<Journal>,
) -> Result<Box<dyn Backend>> {
    match node {
        PlanNode::Die { engine, chip } => build_die(*engine, *chip, nominal, opts, journal),
        PlanNode::Pipeline { shards, batch, chip_base } => {
            let popts = PipelineOptions {
                dies: *shards,
                tile: TILE,
                params: opts.trial,
                variation: opts.variation.clone(),
                seed: opts.seed,
                chip_base: *chip_base,
                min_trials: opts.scheduler.min_trials,
                depth: opts.depth,
                max_in_flight: opts.scheduler.max_in_flight,
                batch: batch.unwrap_or(opts.batch).max(1),
                journal: Some(journal.clone()),
            };
            Ok(Box::new(PipelinedFleetBackend::start(nominal, popts)?))
        }
        // The process boundary: dies on the other side belong to the
        // listener (its weights, its seed, its chip numbering).
        PlanNode::Remote { addr } => build_remote(addr, opts, journal),
        // Replicate and Group share one runtime (children behind a
        // health-reweighted router); Replicate-over-native-die fuses into
        // the per-chip worker fleet first.
        PlanNode::Replicate { policy, children } | PlanNode::Group { policy, children } => {
            if matches!(node, PlanNode::Replicate { .. }) {
                if let Some(fused) =
                    fuse_native_dies(children, *policy, nominal, opts, journal)?
                {
                    return Ok(fused);
                }
            }
            let built = children
                .iter()
                .map(|c| build_node(c, nominal, opts, journal))
                .collect::<Result<Vec<_>>>()?;
            let labels = children.iter().map(node_label).collect();
            Ok(Box::new(RouterBackend::start_labeled(
                built,
                *policy,
                probe_injector(opts),
                opts.reweigh_every,
                node_label(node),
                labels,
                journal.clone(),
            )))
        }
    }
}

/// A `remote:` leaf at build time.  Plain `host:port` addresses connect
/// directly; `@<host:port>/<bundle>` addresses resolve the bundle through
/// the registry first — advertisement check, signature verification under
/// the local deployment key — and journal `bundle_resolved` on success or
/// `manifest_rejected` (and fail the build) on any discrepancy.
fn build_remote(
    addr: &str,
    opts: &BuildOptions,
    journal: &Arc<Journal>,
) -> Result<Box<dyn Backend>> {
    let Some(spec) = addr.strip_prefix('@') else {
        return Ok(Box::new(RemoteBackend::connect(addr)?.with_journal(journal.clone())));
    };
    let node = format!("remote:{addr}");
    let (host_port, bundle) =
        spec.split_once('/').ok_or_else(|| anyhow!("remote:{addr}: malformed address"))?;
    let dir = opts
        .artifact_dir
        .clone()
        .unwrap_or_else(crate::runtime::default_artifact_dir);
    let resolved = crate::registry::SigningKey::load(&crate::registry::key_path(&dir))
        .context("loading the deployment signing key (publish once to create it)")
        .and_then(|key| {
            crate::registry::resolve(host_port, bundle, &key).map(|env| (env, key))
        });
    let (env, key) = match resolved {
        Ok(pair) => pair,
        Err(e) => {
            journal.record(EventKind::ManifestRejected, &node, format!("{e:#}"));
            return Err(e.context(format!("resolving {node}")));
        }
    };
    journal.record(
        EventKind::BundleResolved,
        &node,
        format!(
            "bundle {bundle} ({} {:?}, key {})",
            env.manifest.model, env.manifest.widths, env.key_id
        ),
    );
    // The session keeps the bundle id *and* the key: its reconnect
    // supervisor re-runs this exact resolve before adopting a redialed
    // peer, so a listener restarted with different weights is rejected
    // (`manifest_rejected`), not silently served.
    Ok(Box::new(
        RemoteBackend::connect(host_port)?
            .with_journal(journal.clone())
            .with_bundle(bundle.to_string(), key),
    ))
}

/// Replicate-over-native-die fuses into the per-chip worker backend (one
/// thread per die, live recalibration) instead of a router over N
/// single-chip schedulers — same tree semantics, tighter runtime.
fn fuse_native_dies(
    children: &[PlanNode],
    policy: RoutePolicy,
    nominal: &Weights,
    opts: &BuildOptions,
    journal: &Arc<Journal>,
) -> Result<Option<Box<dyn Backend>>> {
    let mut base = None;
    for (i, c) in children.iter().enumerate() {
        match c {
            PlanNode::Die { engine: EngineSel::Native, chip } => {
                let b = *base.get_or_insert(*chip);
                debug_assert_eq!(*chip, b + i, "replica chip ids must be contiguous");
            }
            _ => return Ok(None),
        }
    }
    let Some(base) = base else { return Ok(None) };
    let variation = opts.variation.clone().unwrap_or_default();
    let mut fleet = Fleet::program_native_span(
        nominal,
        children.len(),
        base,
        &variation,
        policy,
        opts.seed,
    );
    // The worker fleet's engines run the blocked kernel per request.
    for c in fleet.chips.iter_mut() {
        c.engine.block = opts.trial_block.max(1);
    }
    if opts.variation.is_some() {
        if let Some((cal, calibrator)) = &opts.calibration {
            fleet.calibrate(cal, calibrator);
        }
    }
    Ok(Some(Box::new(ReplicatedFleetBackend::start(
        fleet,
        opts.calibration.clone(),
        ReplicatedOptions {
            seed: opts.seed,
            min_trials: opts.scheduler.min_trials,
            reweigh_every: opts.reweigh_every,
            probe_rate: opts.probe_rate,
            label_base: base,
            journal: Some(journal.clone()),
        },
    ))))
}

fn build_die(
    engine: EngineSel,
    chip: ChipId,
    nominal: &Weights,
    opts: &BuildOptions,
    journal: &Arc<Journal>,
) -> Result<Box<dyn Backend>> {
    match engine {
        EngineSel::Native => {
            // A die is a physical chip: programming variation applies when
            // configured, keyed by the fleet-wide chip id; the *trial*
            // stream stays the deployment seed so the `(seed, trial_idx)`
            // parity discipline holds at any tree position.
            let w = match &opts.variation {
                Some(v) => {
                    let mut gauss =
                        GaussianSource::new(chip_seed(opts.seed, chip) ^ 0xD1E_5EED);
                    program_weights(nominal, v, &mut gauss)
                }
                None => nominal.clone(),
            };
            let mut cfg = opts.scheduler.clone();
            cfg.params = opts.trial;
            cfg.seed = opts.seed;
            let e = NativeEngine::new(Arc::new(w), opts.seed).with_trial_block(opts.trial_block);
            Ok(Box::new(
                SingleChipBackend::start(e, cfg)
                    .with_telemetry(format!("die#{chip}"), journal.clone()),
            ))
        }
        EngineSel::Physical => {
            // The physical engine speaks `TrialEngine` (not the batched
            // scheduler's `TrialRunner`), so it serves as a 1-die worker
            // group — with the same fleet-wide RNG identity discipline as
            // a native die: `chip_seed(seed, global chip id)`.
            let variation = opts.variation.clone().unwrap_or_default();
            let die =
                Chip::program_physical_global(0, chip, nominal, &variation, TILE, opts.seed);
            let fleet = Fleet {
                chips: vec![die],
                router: Router::new(RoutePolicy::RoundRobin),
                health: HealthMonitor::new(1, HealthConfig::default()),
                seed: opts.seed,
            };
            Ok(Box::new(ReplicatedFleetBackend::start(
                fleet,
                None,
                ReplicatedOptions {
                    seed: opts.seed,
                    min_trials: opts.scheduler.min_trials,
                    reweigh_every: opts.reweigh_every,
                    probe_rate: opts.probe_rate,
                    label_base: chip,
                    journal: Some(journal.clone()),
                },
            )))
        }
        EngineSel::Pjrt => build_pjrt_die(opts, journal),
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt_die(opts: &BuildOptions, journal: &Arc<Journal>) -> Result<Box<dyn Backend>> {
    // An XLA die takes its weights from the compiled artifact store, not
    // from the nominal weights (they are baked into the executable).
    let dir = opts
        .artifact_dir
        .clone()
        .unwrap_or_else(crate::runtime::default_artifact_dir);
    let engine = crate::engine::XlaEngine::start(dir)?;
    let handle = engine.handle();
    handle.warmup(opts.scheduler.batch_size)?;
    let mut cfg = opts.scheduler.clone();
    cfg.params = opts.trial;
    cfg.seed = opts.seed;
    let inner =
        SingleChipBackend::start(handle, cfg).with_telemetry("die:pjrt", journal.clone());
    Ok(Box::new(PjrtDie { inner, _engine: engine }))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt_die(_opts: &BuildOptions, _journal: &Arc<Journal>) -> Result<Box<dyn Backend>> {
    bail!("die:pjrt needs a build with `--features pjrt` (and compiled artifacts)")
}

/// Keeps the PJRT worker alive for as long as its scheduler serves.
#[cfg(feature = "pjrt")]
struct PjrtDie {
    inner: SingleChipBackend,
    _engine: crate::engine::XlaEngine,
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtDie {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        self.inner.submit_to(req, reply)
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.inner.metrics()
    }

    fn metrics_tree(&self) -> MetricsTree {
        self.inner.metrics_tree()
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        self.inner.journal()
    }

    fn shutdown(self: Box<Self>) {
        drop(self);
    }
}

/// One batched-scheduler die over an explicit engine — the `raca infer`
/// path, including PJRT handles.  Weights-from-config dies go through
/// [`build`]; this is for callers that already hold an engine.
pub fn single_die<E: TrialRunner + Send + 'static>(
    engine: E,
    cfg: SchedulerConfig,
) -> SingleChipBackend {
    SingleChipBackend::start(engine, cfg)
}

/// Lift an externally programmed (and possibly calibrated) fleet onto the
/// replicated worker-thread backend — the `raca fleet` path, which
/// programs and grid-search-calibrates its farm before serving.  Fleets
/// described purely by a topology go through [`build`] instead.
pub fn lift_fleet<E: TrialEngine + 'static>(
    fleet: Fleet<E>,
    cal: Option<(Dataset, Calibrator)>,
    opts: ReplicatedOptions,
) -> ReplicatedFleetBackend {
    ReplicatedFleetBackend::start(fleet, cal, opts)
}

// ---------------------------------------------------------------------------
// RouterBackend: the generic Replicate/Group combinator at runtime.
// ---------------------------------------------------------------------------

/// Book-keeping for one in-flight routed request, keyed by request id.
struct PendingJob {
    child: usize,
    label: Option<i32>,
    max_trials: u32,
    submitted: Instant,
    /// `None` for injected probes: the relay consumes their responses.
    reply: Option<mpsc::Sender<InferResponse>>,
}

struct RouterShared {
    health: Mutex<HealthMonitor>,
    /// Health-driven router weights, refreshed live.
    weights: Mutex<Vec<f64>>,
    /// In-flight requests per child.
    loads: Vec<AtomicU64>,
    /// In-flight requests by id (the relay removes entries on completion).
    pending: Mutex<HashMap<RequestId, PendingJob>>,
    completed: AtomicU64,
    reweigh_every: u64,
    /// In-band `InferResponse::failed` responses relayed per child.
    errors: Vec<AtomicU64>,
    /// Σ queue wait per child [µs] (router latency − child service time).
    queue_us: Vec<AtomicU64>,
    /// Completions behind each `queue_us` sum.
    waits: Vec<AtomicU64>,
    /// Telemetry names: this node and one per child.
    label: String,
    labels: Vec<String>,
    journal: Arc<Journal>,
}

/// A [`Backend`] routing over child backends — the runtime of a
/// [`Topology::Replicate`] whose child is itself a subtree, and of every
/// [`Topology::Group`] (pipelines, nested replicas, remote hosts,
/// heterogeneous dies).  All children complete into **one** relay
/// channel ([`Backend::submit_to`] with a shared sender), so responses
/// are delivered in completion order — a slow request never delays the
/// delivery of requests that finished behind it — while the single relay
/// thread feeds the shared [`HealthMonitor`] (labeled traffic and
/// injected probes drive accuracy; everything drives
/// latency/abstention) and periodically reweighs traffic / evicts
/// floor-breakers: the same live steering the flat replicated fleet
/// does, one level up.
///
/// Children have no recalibrate hook from up here: fleets recalibrate
/// their *own* dies; the router only reweighs and evicts.
pub struct RouterBackend {
    children: Vec<Box<dyn Backend>>,
    /// The shared completion channel (cloned into every child submit).
    /// `Option` so drop can close it *after* the children flush.
    done_tx: Option<mpsc::Sender<InferResponse>>,
    relay: Option<JoinHandle<()>>,
    router: Router,
    probes: Option<ProbeInjector>,
    shared: Arc<RouterShared>,
    metrics: Arc<Metrics>,
}

impl RouterBackend {
    /// Route over `children` with `policy`; reweigh health every
    /// `reweigh_every` completions; optionally inject labeled probes.
    /// Children get generic `child#i` telemetry names and a private
    /// journal; [`build`] goes through [`RouterBackend::start_labeled`]
    /// to name them after their plan nodes instead.
    pub fn start(
        children: Vec<Box<dyn Backend>>,
        policy: RoutePolicy,
        probes: Option<ProbeInjector>,
        reweigh_every: u64,
    ) -> Self {
        let labels = (0..children.len()).map(|i| format!("child#{i}")).collect();
        Self::start_labeled(
            children,
            policy,
            probes,
            reweigh_every,
            "router".to_string(),
            labels,
            Journal::new(DEFAULT_CAPACITY),
        )
    }

    /// [`RouterBackend::start`] with explicit telemetry names: `label` is
    /// this node's own, `labels[i]` the name child `i`'s subtree is
    /// re-rooted under in the [`MetricsTree`] and in journal events.
    #[allow(clippy::too_many_arguments)]
    pub fn start_labeled(
        children: Vec<Box<dyn Backend>>,
        policy: RoutePolicy,
        probes: Option<ProbeInjector>,
        reweigh_every: u64,
        label: String,
        labels: Vec<String>,
        journal: Arc<Journal>,
    ) -> Self {
        assert!(!children.is_empty(), "a replicate/group node needs at least one child");
        let n = children.len();
        debug_assert_eq!(labels.len(), n, "one telemetry label per child");
        let mut health = HealthMonitor::new(n, HealthConfig::default());
        health.attach_journal(journal.clone(), labels.clone());
        let initial_weights = health.traffic_weights();
        let shared = Arc::new(RouterShared {
            health: Mutex::new(health),
            weights: Mutex::new(initial_weights),
            loads: (0..n).map(|_| AtomicU64::new(0)).collect(),
            pending: Mutex::new(HashMap::new()),
            completed: AtomicU64::new(0),
            reweigh_every: reweigh_every.max(1),
            errors: (0..n).map(|_| AtomicU64::new(0)).collect(),
            queue_us: (0..n).map(|_| AtomicU64::new(0)).collect(),
            waits: (0..n).map(|_| AtomicU64::new(0)).collect(),
            label,
            labels,
            journal,
        });
        let metrics = Metrics::new();
        let (done_tx, done_rx) = mpsc::channel::<InferResponse>();
        let relay = {
            let shared = shared.clone();
            let metrics = metrics.clone();
            std::thread::Builder::new()
                .name("raca-route-relay".into())
                .spawn(move || relay_loop(done_rx, shared, metrics))
                .expect("spawning router relay thread")
        };
        Self {
            children,
            done_tx: Some(done_tx),
            relay: Some(relay),
            router: Router::new(policy),
            probes,
            shared,
            metrics,
        }
    }

    pub fn num_children(&self) -> usize {
        self.children.len()
    }

    /// Child indices still eligible for routing.
    pub fn healthy(&self) -> Vec<ChipId> {
        self.shared.health.lock().unwrap().healthy()
    }

    /// Current health-driven router weights.
    pub fn traffic_weights(&self) -> Vec<f64> {
        self.shared.weights.lock().unwrap().clone()
    }

    /// Health probes injected so far ([`BuildOptions::probe_rate`]).
    pub fn probes_sent(&self) -> u64 {
        self.probes.as_ref().map(|p| p.sent()).unwrap_or(0)
    }

    /// Route one job (caller request or probe) onto a healthy child.
    fn dispatch(
        &self,
        mut req: InferRequest,
        reply: Option<mpsc::Sender<InferResponse>>,
    ) -> Result<()> {
        let healthy = self.shared.health.lock().unwrap().healthy();
        let loads: Vec<u64> = self.shared.loads.iter().map(|l| l.load(Relaxed)).collect();
        let weights = self.shared.weights.lock().unwrap().clone();
        let child = self
            .router
            .pick(&healthy, &loads, &weights)
            .ok_or_else(|| anyhow!("no healthy children left under the router"))?;
        let id = req.id;
        let caller = reply.is_some();
        // Deadline propagation: charge the chosen child's *observed* mean
        // queue wait against the remaining budget before relaying, so
        // depth never inflates the effective deadline — each hop forwards
        // only what will plausibly be left when the child starts.  A
        // request whose whole budget would be eaten by the queue is shed
        // here, in-band, without burning a child slot on it.
        if let Some(d) = req.deadline_ms {
            let waits = self.shared.waits[child].load(Relaxed);
            let wait_ms = if waits == 0 {
                0
            } else {
                self.shared.queue_us[child].load(Relaxed) / waits / 1000
            };
            if d <= wait_ms {
                self.shared.journal.record(
                    EventKind::DeadlineExceeded,
                    &self.shared.label,
                    format!(
                        "id {id}: {}ms budget ≤ {wait_ms}ms observed queue wait on {}",
                        d, self.shared.labels[child]
                    ),
                );
                if let Some(reply) = reply {
                    let _ = reply.send(InferResponse::failed(
                        id,
                        format!(
                            "{DEADLINE_EXCEEDED}: {} shed the request before dispatch \
                             ({wait_ms}ms observed queue wait ≥ {d}ms budget)",
                            self.shared.label
                        ),
                    ));
                }
                return Ok(());
            }
            req.deadline_ms = Some(d - wait_ms);
        }
        {
            let mut pending = self.shared.pending.lock().unwrap();
            if pending.contains_key(&id) {
                bail!("request id {id} is already in flight under this router");
            }
            pending.insert(
                id,
                PendingJob {
                    child,
                    label: req.label,
                    max_trials: req.max_trials,
                    submitted: Instant::now(),
                    reply,
                },
            );
        }
        // Load up BEFORE the child sees the request: a fast completion
        // may hit the relay's decrement before this thread resumes, and
        // the counter must never wrap below zero.
        self.shared.loads[child].fetch_add(1, Relaxed);
        let done_tx = self.done_tx.as_ref().expect("router alive").clone();
        if let Err(e) = self.children[child].submit_to(req, done_tx) {
            self.shared.pending.lock().unwrap().remove(&id);
            self.shared.loads[child].fetch_sub(1, Relaxed);
            // A child that cannot even admit work is as unhealthy as one
            // answering in-band failures: record the observation so the
            // steering pass can evict it, not just the relayed errors.
            self.shared.errors[child].fetch_add(1, Relaxed);
            self.shared.health.lock().unwrap().record(child, Some(false), false, 0);
            self.shared.journal.record(
                EventKind::RequestFailed,
                &self.shared.labels[child],
                format!("id {id}: submit failed: {e:#}"),
            );
            return Err(e);
        }
        if caller {
            self.metrics.requests_admitted.fetch_add(1, Relaxed);
            self.shared.journal.record(
                EventKind::RequestAdmitted,
                &self.shared.label,
                format!("id {id} → {}", self.shared.labels[child]),
            );
        }
        Ok(())
    }
}

impl Backend for RouterBackend {
    fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
        let budget = req.max_trials;
        self.dispatch(req, Some(reply))?;
        // Piggyback a labeled probe when one is due — routed like any
        // request, so the health monitor's accuracy signal stays fed even
        // on fully unlabeled traffic.
        if let Some(probes) = &self.probes {
            if let Some(probe) = probes.next(budget) {
                if let Err(e) = self.dispatch(probe, None) {
                    log::warn!("probe injection failed: {e:#}");
                }
            }
        }
        Ok(())
    }

    fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    fn metrics_tree(&self) -> MetricsTree {
        // Collect child subtrees before touching our own locks: a remote
        // child's tree is fetched over the wire and may block; holding the
        // health lock across that would stall the relay thread.
        let mut children: Vec<MetricsTree> =
            self.children.iter().map(|c| c.metrics_tree()).collect();
        let weights = self.shared.weights.lock().unwrap().clone();
        let health = self.shared.health.lock().unwrap();
        for (i, child) in children.iter_mut().enumerate() {
            let h = health.chip(i);
            // Re-root the child under its plan-node name: a bare die's
            // own tree calls itself `die`; the router knows it as `die#3`.
            child.label = self.shared.labels[i].clone();
            child.notes.service_us = Some(h.mean_latency_us());
            let waits = self.shared.waits[i].load(Relaxed);
            if waits > 0 {
                child.notes.queue_wait_us =
                    Some(self.shared.queue_us[i].load(Relaxed) as f64 / waits as f64);
            }
            child.notes.probe_accuracy = h.rolling_accuracy();
            child.notes.evicted = Some(h.evicted);
            child.notes.errors = Some(self.shared.errors[i].load(Relaxed));
            child.notes.weight = weights.get(i).copied();
        }
        drop(health);
        MetricsTree::leaf(self.shared.label.clone(), self.metrics()).with_children(children)
    }

    fn journal(&self) -> Option<Arc<Journal>> {
        Some(self.shared.journal.clone())
    }

    fn shutdown(self: Box<Self>) {
        drop(self);
    }
}

impl Drop for RouterBackend {
    fn drop(&mut self) {
        // Children first: each finishes its in-flight work and flushes the
        // responses into the still-running relay (callers' waits complete
        // across shutdown).  Then closing our completion sender ends the
        // relay once it has drained.
        for c in self.children.drain(..) {
            c.shutdown();
        }
        self.done_tx.take();
        if let Some(r) = self.relay.take() {
            let _ = r.join();
        }
    }
}

/// The single completion relay: responses from *all* children arrive
/// here in completion order; each is matched to its pending entry,
/// recorded, and forwarded to its caller immediately.
fn relay_loop(
    done_rx: mpsc::Receiver<InferResponse>,
    shared: Arc<RouterShared>,
    metrics: Arc<Metrics>,
) {
    while let Ok(resp) = done_rx.recv() {
        let Some(job) = shared.pending.lock().unwrap().remove(&resp.id) else {
            log::warn!("router relay: response for unknown request {}", resp.id);
            continue;
        };
        shared.loads[job.child].fetch_sub(1, Relaxed);
        let latency = job.submitted.elapsed();
        let child_label = &shared.labels[job.child];
        if let Some(msg) = &resp.error {
            // An in-band failure (dead remote peer, duplicate id
            // downstream) IS a health observation: the child was picked,
            // failed to answer, and must lose routing weight — a child
            // that fails every request would otherwise never be evicted
            // (pre-PR-6 this branch recorded nothing, so a dead remote
            // kept its full share of traffic forever).
            shared.errors[job.child].fetch_add(1, Relaxed);
            metrics.engine_errors.fetch_add(1, Relaxed);
            shared.journal.record(
                EventKind::RequestFailed,
                child_label,
                format!("id {}: {msg}", resp.id),
            );
            if job.max_trials > 0 {
                shared.health.lock().unwrap().record(
                    job.child,
                    Some(false), // a failure is a known-wrong answer
                    false,
                    latency.as_micros() as u64,
                );
            }
            if let Some(reply) = job.reply {
                let _ = reply.send(resp);
            }
        } else {
            let abstained =
                resp.outcome.trials > 0 && resp.outcome.abstentions == resp.outcome.trials;
            let correct = job.label.map(|l| resp.prediction == l);
            // The child-reported latency is the service-time signal; the
            // router's own `latency` additionally includes queue wait and
            // is what this backend's metrics report.
            let service_us = resp.latency.as_micros() as u64;
            let wait_us = (latency.as_micros() as u64).saturating_sub(service_us);
            shared.queue_us[job.child].fetch_add(wait_us, Relaxed);
            shared.waits[job.child].fetch_add(1, Relaxed);
            if job.max_trials > 0 {
                shared.health.lock().unwrap().record(job.child, correct, abstained, service_us);
            }
            // Probe trials are real engine work (counted); probes are not
            // caller traffic (request counters/latency stay caller-only).
            metrics.trials_executed.fetch_add(resp.trials_used as u64, Relaxed);
            if let Some(reply) = job.reply {
                metrics
                    .trials_saved
                    .fetch_add(job.max_trials.saturating_sub(resp.trials_used) as u64, Relaxed);
                metrics.requests_completed.fetch_add(1, Relaxed);
                metrics.record_latency(latency);
                shared.journal.record(
                    EventKind::RequestCompleted,
                    child_label,
                    format!("id {} trials {}", resp.id, resp.trials_used),
                );
                let _ = reply.send(resp);
            } else if job.label.is_some() {
                let verdict = match correct {
                    Some(true) => "hit",
                    Some(false) => "miss",
                    None => "unlabeled",
                };
                shared.journal.record(
                    EventKind::ProbeVerdict,
                    child_label,
                    format!("id {} {verdict}", resp.id),
                );
            }
        }
        // Failures participate in the steering cadence too: a child that
        // only ever fails still drives reweigh/evict passes.
        let done = shared.completed.fetch_add(1, Relaxed) + 1;
        if done % shared.reweigh_every == 0 {
            let steer = shared.health.lock().unwrap().steer();
            *shared.weights.lock().unwrap() = steer.weights;
        }
    }
    // All senders gone (teardown): anything still pending will never
    // complete — drop the reply senders so blocked waits error out.
    shared.pending.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ModelSpec;

    fn parse(s: &str) -> Topology {
        Topology::parse(s).unwrap()
    }

    #[test]
    fn grammar_round_trips_through_display() {
        for spec in [
            "die",
            "die:physical",
            "die:pjrt",
            "pipeline:3",
            "pipeline:4:b16",
            "2x(die)",
            "8x(die)@weighted",
            "2x(pipeline:3)",
            "3x(pipeline:2:b4)@least-loaded",
            "2x(2x(die)@weighted)",
            "remote:10.0.0.7:7433",
            "2x(remote:10.0.0.7:7433)",
            "(remote:a:1, remote:b:2)",
            "(remote:a:1, pipeline:2)@weighted",
            "2x((remote:a:1, remote:b:2))",
            "(die, die, die)@least-loaded",
        ] {
            let t = parse(spec);
            assert_eq!(t.to_string(), spec, "canonical spelling");
            assert_eq!(parse(&t.to_string()), t, "round trip");
        }
    }

    #[test]
    fn parse_is_case_insensitive_and_whitespace_tolerant() {
        assert_eq!(parse("2X(PIPELINE:3)"), parse("2x(pipeline:3)"));
        assert_eq!(parse("Die:Physical"), parse("die:physical"));
        assert_eq!(parse(" 4x( die )@Weighted "), parse("4x(die)@weighted"));
        assert_eq!(parse("2xdie"), parse("2x(die)"));
        assert_eq!(parse("2x4x(die)").dies(), 8);
    }

    #[test]
    fn remote_and_group_parse_with_clear_errors() {
        // Addresses run to the next structural character; case folding is
        // harmless (DNS names are case-insensitive).
        assert_eq!(
            parse("Remote:Host.Example:7433"),
            Topology::Remote { addr: "host.example:7433".into() }
        );
        assert_eq!(parse(" ( remote:a:1 , remote:b:2 ) "), parse("(remote:a:1, remote:b:2)"));
        // A remote leaf owns no local dies; groups sum their children.
        assert_eq!(parse("remote:a:1").dies(), 0);
        assert_eq!(parse("(remote:a:1, pipeline:3)").dies(), 3);
        assert_eq!(parse("2x((remote:a:1, remote:b:2))").dies(), 0);
        // Errors: missing address, missing port, dangling commas.
        assert!(Topology::parse("remote").is_err());
        assert!(Topology::parse("remote:").is_err());
        let e = format!("{:#}", Topology::parse("remote:justahost").unwrap_err());
        assert!(e.contains("host:port"), "unhelpful: {e}");
        assert!(Topology::parse("(die, die").is_err());
        assert!(Topology::parse("(die,)").is_err());
        let e = format!("{:#}", Topology::parse("(die, die)@fastest").unwrap_err());
        assert!(e.contains("round-robin"), "unhelpful: {e}");
        // Programmatic empty groups die at compile.
        let t = Topology::Group { policy: RoutePolicy::RoundRobin, children: vec![] };
        assert!(DeployPlan::compile(&t).is_err());
    }

    #[test]
    fn registry_remote_form_parses_and_validates() {
        // `remote:@<host:port>/<bundle>` round-trips through Display like
        // any other address (bundle ids are lowercase hex, so the parser's
        // case folding is a no-op on well-formed specs).
        let bundle = "ab".repeat(32);
        let spec = format!("remote:@10.0.0.7:7433/{bundle}");
        let t = parse(&spec);
        assert_eq!(t, Topology::Remote { addr: format!("@10.0.0.7:7433/{bundle}") });
        assert_eq!(t.to_string(), spec, "canonical spelling");
        assert_eq!(parse(&t.to_string()), t, "round trip");
        // Registry leaves are still remote leaves: no local dies, and they
        // compose under groups and replication.
        assert_eq!(t.dies(), 0);
        assert_eq!(parse(&format!("({spec}, pipeline:2)")).dies(), 2);
        DeployPlan::compile(&t).unwrap();
        // Errors: missing bundle, missing port, non-hex / short bundle ids.
        let e = format!("{:#}", Topology::parse("remote:@host:7433").unwrap_err());
        assert!(e.contains("@<host:port>/<bundle-id>"), "unhelpful: {e}");
        let e = format!("{:#}", Topology::parse(&format!("remote:@host/{bundle}")).unwrap_err());
        assert!(e.contains("@<host:port>/<bundle-id>"), "unhelpful: {e}");
        let e = format!("{:#}", Topology::parse("remote:@host:7433/nothex").unwrap_err());
        assert!(e.contains("not a bundle id"), "unhelpful: {e}");
        let e = format!(
            "{:#}",
            Topology::parse(&format!("remote:@host:7433/{}", &bundle[..40])).unwrap_err()
        );
        assert!(e.contains("not a bundle id"), "unhelpful: {e}");
    }

    #[test]
    fn remote_leaves_consume_no_chip_ids() {
        let plan = DeployPlan::compile(&parse("(pipeline:2, remote:h:1, die)")).unwrap();
        assert_eq!(plan.total_dies, 3, "2 pipeline dies + 1 die, none for the remote");
        let desc = plan.describe(&ModelSpec::paper());
        assert!(desc.contains("remote h:1"), "{desc}");
        assert!(desc.contains("group × 3"), "{desc}");
        assert!(desc.contains("die [chip 2]"), "{desc}");
    }

    #[test]
    fn parse_errors_name_the_valid_spellings() {
        let e = format!("{:#}", Topology::parse("blob").unwrap_err());
        assert!(e.contains("die") && e.contains("pipeline"), "unhelpful: {e}");
        let e = format!("{:#}", Topology::parse("2x(die)@fastest").unwrap_err());
        assert!(e.contains("round-robin"), "unhelpful: {e}");
        let e = format!("{:#}", Topology::parse("die:gpu").unwrap_err());
        assert!(e.contains("native") && e.contains("physical"), "unhelpful: {e}");
        assert!(Topology::parse("pipeline").is_err());
        assert!(Topology::parse("2x(die").is_err());
        assert!(Topology::parse("die die").is_err());
    }

    #[test]
    fn zero_sized_nodes_are_rejected() {
        assert!(Topology::parse("0x(die)").is_err());
        assert!(Topology::parse("pipeline:0").is_err());
        assert!(Topology::parse("pipeline:2:b0").is_err());
        assert!(Topology::parse("2x(0x(die))").is_err());
        // Programmatically built trees hit the same validation in compile.
        let t = Topology::Replicate {
            n: 0,
            policy: RoutePolicy::RoundRobin,
            child: Box::new(Topology::Die { engine: EngineSel::Native }),
        };
        assert!(DeployPlan::compile(&t).is_err());
    }

    #[test]
    fn compile_numbers_every_die_once() {
        let plan = DeployPlan::compile(&parse("2x(pipeline:3)")).unwrap();
        assert_eq!(plan.total_dies, 6);
        let PlanNode::Replicate { children, .. } = &plan.root else {
            panic!("expected replicate root")
        };
        let bases: Vec<usize> = children
            .iter()
            .map(|c| match c {
                PlanNode::Pipeline { chip_base, shards: 3, .. } => *chip_base,
                other => panic!("expected 3-die pipeline, got {other:?}"),
            })
            .collect();
        assert_eq!(bases, vec![0, 3]);

        let plan = DeployPlan::compile(&parse("2x(2x(die))")).unwrap();
        assert_eq!(plan.total_dies, 4);
        let desc = plan.describe(&ModelSpec::paper());
        assert_eq!(desc.matches("die [chip").count(), 4, "{desc}");
    }

    #[test]
    fn describe_renders_shard_detail() {
        let plan = DeployPlan::compile(&parse("2x(pipeline:2)")).unwrap();
        let desc = plan.describe(&ModelSpec::paper());
        assert!(desc.contains("replicate × 2"), "{desc}");
        assert!(desc.contains("chips 0..2") && desc.contains("chips 2..4"), "{desc}");
        assert!(desc.contains("layer ranges"), "{desc}");
    }

    #[test]
    fn router_backend_spreads_load_and_tracks_health() {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let opts = BuildOptions::default();
        let children: Vec<Box<dyn Backend>> = (0..2)
            .map(|_| build(&parse("die"), &w, &opts).unwrap())
            .collect();
        let b = RouterBackend::start(children, RoutePolicy::RoundRobin, None, 8);
        assert_eq!(b.num_children(), 2);
        let tickets: Vec<_> = (0..10u64)
            .map(|i| {
                let img = vec![(i % 5) as f32 / 5.0; 784];
                b.submit(InferRequest::new(i, img).with_budget(4, 0.0).with_label(0)).unwrap()
            })
            .collect();
        for t in tickets {
            assert_eq!(b.wait(t).unwrap().trials_used, 4);
        }
        let m = b.metrics();
        assert_eq!(m.requests_completed, 10);
        assert_eq!(m.trials_executed, 40);
        assert_eq!(b.healthy(), vec![0, 1]);
        assert_eq!(b.traffic_weights().len(), 2);
        // Labeled probes reached the health monitor.
        let h = b.shared.health.lock().unwrap();
        let labeled: usize = (0..2).map(|c| h.chip(c).labeled_samples()).sum();
        assert_eq!(labeled, 10);
    }

    type HeldJob = (InferRequest, mpsc::Sender<InferResponse>);

    /// Test double for the completion-order contract: completes every
    /// request immediately except the one id it is told to hold.
    #[derive(Default)]
    struct Gate {
        held: Mutex<Vec<HeldJob>>,
    }

    impl Gate {
        fn release(&self) {
            for (req, tx) in self.held.lock().unwrap().drain(..) {
                let _ = tx.send(canned_response(&req));
            }
        }
    }

    fn canned_response(req: &InferRequest) -> InferResponse {
        InferResponse {
            id: req.id,
            prediction: 0,
            outcome: crate::neuron::WtaOutcome::new(10),
            trials_used: req.max_trials,
            latency: std::time::Duration::from_micros(1),
            error: None,
        }
    }

    struct OutOfOrderChild {
        gate: Arc<Gate>,
        hold: u64,
    }

    impl Backend for OutOfOrderChild {
        fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
            if req.id == self.hold {
                self.gate.held.lock().unwrap().push((req, reply));
            } else {
                let _ = reply.send(canned_response(&req));
            }
            Ok(())
        }

        fn metrics(&self) -> MetricsSnapshot {
            Metrics::new().snapshot()
        }

        fn shutdown(self: Box<Self>) {}
    }

    impl Drop for OutOfOrderChild {
        fn drop(&mut self) {
            // Abandon held requests so the router relay can drain at
            // teardown even when a test fails before releasing the gate.
            self.gate.held.lock().unwrap().clear();
        }
    }

    /// Regression for the PR-3 note: relays delivered completions FIFO
    /// per child, so one slow request inflated the tail latency of every
    /// request that finished behind it on the same child.  Delivery is
    /// now completion-order.
    #[test]
    fn router_delivers_completions_out_of_submission_order() {
        let gate = Arc::new(Gate::default());
        let child: Box<dyn Backend> =
            Box::new(OutOfOrderChild { gate: gate.clone(), hold: 0 });
        let b = RouterBackend::start(vec![child], RoutePolicy::RoundRobin, None, 8);
        let slow = b.submit(InferRequest::new(0, vec![0.1; 4]).with_budget(4, 0.0)).unwrap();
        let fast = b.submit(InferRequest::new(1, vec![0.2; 4]).with_budget(4, 0.0)).unwrap();
        // Request 1 finished first and must be delivered while request 0
        // is still in flight — a FIFO relay parks it behind 0 forever.
        let r = fast
            .rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("request 1 completed but its delivery was blocked behind request 0");
        assert_eq!(r.id, 1);
        gate.release();
        assert_eq!(b.wait(slow).unwrap().id, 0);
        assert_eq!(b.metrics().requests_completed, 2);
    }

    #[test]
    fn router_probes_feed_health_on_unlabeled_traffic() {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let opts = BuildOptions::default();
        let children: Vec<Box<dyn Backend>> = (0..2)
            .map(|_| build(&parse("die"), &w, &opts).unwrap())
            .collect();
        let cal = crate::dataset::synth::generate(8, 0xCA1);
        let probes = ProbeInjector::new(cal, 1.0);
        assert!(probes.is_some());
        let b = RouterBackend::start(children, RoutePolicy::RoundRobin, probes, 8);
        let tickets: Vec<_> = (0..6u64)
            .map(|i| {
                // Callers never label anything.
                let img = vec![(i % 5) as f32 / 5.0; 784];
                b.submit(InferRequest::new(i, img).with_budget(4, 0.0)).unwrap()
            })
            .collect();
        for t in tickets {
            b.wait(t).unwrap();
        }
        assert_eq!(b.probes_sent(), 6, "rate 1.0 ⇒ one probe per request");
        // Probes are invisible in caller-facing request metrics.
        let m = b.metrics();
        assert_eq!(m.requests_admitted, 6);
        assert_eq!(m.requests_completed, 6);
        let shared = b.shared.clone();
        Box::new(b).shutdown(); // flush in-flight probes deterministically
        let h = shared.health.lock().unwrap();
        let labeled: usize = (0..2).map(|c| h.chip(c).labeled_samples()).sum();
        assert_eq!(labeled, 6, "every probe reached the health monitor");
    }

    /// A child whose every response is an in-band failure — the shape of
    /// a dead remote peer behind a still-connected socket.
    struct FailingChild;

    impl Backend for FailingChild {
        fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
            let _ = reply.send(InferResponse::failed(req.id, "simulated dead peer"));
            Ok(())
        }

        fn metrics(&self) -> MetricsSnapshot {
            Metrics::new().snapshot()
        }

        fn shutdown(self: Box<Self>) {}
    }

    /// S1 regression: in-band failures must count against the child's
    /// health.  Pre-PR-6 the relay forwarded `InferResponse::failed` and
    /// recorded nothing, so a dead child kept its routing share forever.
    #[test]
    fn router_evicts_a_child_that_only_fails() {
        let w = Weights::random(ModelSpec::new(vec![784, 12, 10]), 5);
        let healthy = build(&parse("die"), &w, &BuildOptions::default()).unwrap();
        let children: Vec<Box<dyn Backend>> = vec![Box::new(FailingChild), healthy];
        let b = RouterBackend::start(children, RoutePolicy::RoundRobin, None, 4);
        let mut failures = 0;
        for i in 0..60u64 {
            let t = b.submit(InferRequest::new(i, vec![0.2; 784]).with_budget(3, 0.0)).unwrap();
            if b.wait(t).is_err() {
                failures += 1;
            }
        }
        // Enough failures accumulated (min_samples) → the steering pass
        // evicted the dead child; routing now avoids it entirely.
        assert!(failures >= HealthConfig::default().min_samples, "dead child saw traffic");
        assert_eq!(b.healthy(), vec![1], "failing child must be evicted");
        let evs = b.journal().unwrap().tail(1024);
        assert!(
            evs.iter().any(|e| e.kind == EventKind::HealthEvict && e.node == "child#0"),
            "eviction must land in the journal: {evs:?}"
        );
        assert!(evs.iter().any(|e| e.kind == EventKind::RequestFailed && e.node == "child#0"));
        // The failed-child request count stops growing post-eviction.
        let errs_at_eviction = b.metrics().engine_errors;
        for i in 100..120u64 {
            let t = b.submit(InferRequest::new(i, vec![0.2; 784]).with_budget(3, 0.0)).unwrap();
            b.wait(t).expect("post-eviction traffic must route to the healthy child");
        }
        assert_eq!(b.metrics().engine_errors, errs_at_eviction);
        // The telemetry tree shows the eviction and the error count.
        let tree = b.metrics_tree();
        assert_eq!(tree.children[0].notes.evicted, Some(true));
        assert_eq!(tree.children[0].notes.errors, Some(errs_at_eviction));
        assert_eq!(tree.children[1].notes.evicted, Some(false));
    }

    /// A child that sits on every request for `delay` before answering
    /// with near-zero reported service time — so each completion teaches
    /// the router ~`delay` of pure queue wait — while recording the
    /// deadline each relayed request arrived with.
    struct SlowChild {
        delay: std::time::Duration,
        seen: Arc<Mutex<Vec<Option<u64>>>>,
    }

    impl Backend for SlowChild {
        fn submit_to(&self, req: InferRequest, reply: mpsc::Sender<InferResponse>) -> Result<()> {
            self.seen.lock().unwrap().push(req.deadline_ms);
            std::thread::sleep(self.delay);
            let _ = reply.send(canned_response(&req));
            Ok(())
        }

        fn metrics(&self) -> MetricsSnapshot {
            Metrics::new().snapshot()
        }

        fn shutdown(self: Box<Self>) {}
    }

    /// Deadline propagation at the router: the observed mean queue wait
    /// of the chosen child is subtracted from the budget before relaying,
    /// and a budget the queue would fully consume is shed in-band before
    /// dispatch — journaled, never silently forwarded to rot downstream.
    #[test]
    fn router_charges_observed_queue_wait_against_the_deadline() {
        let seen = Arc::new(Mutex::new(Vec::new()));
        let child: Box<dyn Backend> = Box::new(SlowChild {
            delay: std::time::Duration::from_millis(30),
            seen: seen.clone(),
        });
        let b = RouterBackend::start(vec![child], RoutePolicy::RoundRobin, None, 1024);
        // Warm-up: teach the router this child queues ≥30ms per request.
        for i in 0..4u64 {
            let t = b.submit(InferRequest::new(i, vec![0.1; 4]).with_budget(2, 0.0)).unwrap();
            b.wait(t).unwrap();
        }
        assert!(
            seen.lock().unwrap().iter().all(|d| d.is_none()),
            "undeadlined requests must relay undeadlined"
        );
        // A generous budget arrives at the child minus the observed wait.
        let t = b
            .submit(
                InferRequest::new(10, vec![0.1; 4]).with_budget(2, 0.0).with_deadline_ms(10_000),
            )
            .unwrap();
        b.wait(t).unwrap();
        let relayed =
            seen.lock().unwrap().last().copied().flatten().expect("deadline survives the relay");
        assert!(
            relayed <= 10_000 - 30,
            "queue wait was not charged: relayed {relayed} of a 10000ms budget"
        );
        assert!(relayed >= 5_000, "implausibly large wait estimate: relayed {relayed}");
        // A budget below the observed wait is shed before dispatch.
        let t = b
            .submit(InferRequest::new(11, vec![0.1; 4]).with_budget(2, 0.0).with_deadline_ms(5))
            .unwrap();
        let e = b.wait(t).unwrap_err();
        assert!(
            format!("{e:#}").contains(DEADLINE_EXCEEDED),
            "shed must carry the matchable prefix: {e:#}"
        );
        assert_eq!(
            seen.lock().unwrap().len(),
            5,
            "the shed request must never reach the child"
        );
        let evs = b.journal().unwrap().tail(64);
        assert!(
            evs.iter().any(|e| e.kind == EventKind::DeadlineExceeded),
            "the shed must be journaled: {evs:?}"
        );
        Box::new(b).shutdown();
    }

    #[test]
    fn replicated_pipelines_serve_and_complete() {
        let w = Weights::random(ModelSpec::new(vec![784, 16, 12, 10]), 11);
        let b = build(&parse("2x(pipeline:3)"), &w, &BuildOptions::default()).unwrap();
        let tickets: Vec<_> = (0..8u64)
            .map(|i| {
                let img = vec![(i % 3) as f32 / 3.0; 784];
                b.submit(InferRequest::new(i, img).with_budget(6, 0.0)).unwrap()
            })
            .collect();
        for t in tickets {
            assert_eq!(b.wait(t).unwrap().trials_used, 6);
        }
        assert_eq!(b.metrics().requests_completed, 8);
        b.shutdown();
    }

    #[test]
    fn fused_replicate_matches_the_flat_fleet_programming() {
        // `3x(die)` at σ>0 must program the same three dies as the flat
        // PR-1 fleet at the same seed — the compatibility mapping is
        // bit-exact, not just shape-equivalent.
        let w = Weights::random(ModelSpec::new(vec![784, 10, 10]), 4);
        let variation = VariationModel::lognormal(0.08);
        let flat = Fleet::program_native(&w, 3, &variation, RoutePolicy::RoundRobin, 99);
        let spanned = Fleet::program_native_span(&w, 3, 0, &variation, RoutePolicy::RoundRobin, 99);
        for (a, b) in flat.chips.iter().zip(&spanned.chips) {
            assert_eq!(a.engine.weights.mats, b.engine.weights.mats);
        }
        // A second replica group (chips 3..6) programs different silicon.
        let shifted = Fleet::program_native_span(&w, 3, 3, &variation, RoutePolicy::RoundRobin, 99);
        assert_ne!(
            flat.chips[0].engine.weights.mats,
            shifted.chips[0].engine.weights.mats
        );
    }
}
