//! Two-sample Kolmogorov–Smirnov test — used by the engine-parity suite
//! to compare whole *distributions* (not just means) across engines.

/// KS statistic D = sup |F1(x) − F2(x)| for two samples.
pub fn ks_statistic(a: &mut [f64], b: &mut [f64]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty());
    a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let (mut i, mut j) = (0usize, 0usize);
    let (na, nb) = (a.len() as f64, b.len() as f64);
    let mut d: f64 = 0.0;
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            i += 1;
        } else {
            j += 1;
        }
        d = d.max((i as f64 / na - j as f64 / nb).abs());
    }
    d
}

/// Approximate p-value for the two-sample KS statistic (asymptotic
/// Kolmogorov distribution; good for n ≳ 35).
pub fn ks_pvalue(d: f64, n1: usize, n2: usize) -> f64 {
    let n = (n1 * n2) as f64 / (n1 + n2) as f64;
    let lambda = (n.sqrt() + 0.12 + 0.11 / n.sqrt()) * d;
    // P = 2 Σ (−1)^{k−1} e^{−2 k² λ²}
    let mut p = 0.0;
    let mut sign = 1.0;
    for k in 1..=100 {
        let term = (-2.0 * (k as f64) * (k as f64) * lambda * lambda).exp();
        p += sign * term;
        sign = -sign;
        if term < 1e-10 {
            break;
        }
    }
    (2.0 * p).clamp(0.0, 1.0)
}

/// Convenience: do two samples plausibly come from the same distribution?
pub fn same_distribution(a: &[f64], b: &[f64], alpha: f64) -> bool {
    let mut a = a.to_vec();
    let mut b = b.to_vec();
    let d = ks_statistic(&mut a, &mut b);
    ks_pvalue(d, a.len(), b.len()) > alpha
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::GaussianSource;

    fn normals(seed: u64, n: usize, mu: f64, sd: f64) -> Vec<f64> {
        let mut g = GaussianSource::new(seed);
        (0..n).map(|_| g.sample(mu, sd)).collect()
    }

    #[test]
    fn identical_distributions_pass() {
        let a = normals(1, 3000, 0.0, 1.0);
        let b = normals(2, 3000, 0.0, 1.0);
        assert!(same_distribution(&a, &b, 0.01));
    }

    #[test]
    fn shifted_distributions_fail() {
        let a = normals(3, 3000, 0.0, 1.0);
        let b = normals(4, 3000, 0.4, 1.0);
        assert!(!same_distribution(&a, &b, 0.01));
    }

    #[test]
    fn scaled_distributions_fail() {
        let a = normals(5, 4000, 0.0, 1.0);
        let b = normals(6, 4000, 0.0, 1.6);
        assert!(!same_distribution(&a, &b, 0.01));
    }

    #[test]
    fn statistic_bounds() {
        let mut a = vec![1.0, 2.0, 3.0];
        let mut b = vec![10.0, 11.0];
        let d = ks_statistic(&mut a, &mut b);
        assert!((d - 1.0).abs() < 1e-12, "disjoint supports → D = 1");
    }

    #[test]
    fn pvalue_monotone_in_d() {
        assert!(ks_pvalue(0.01, 1000, 1000) > ks_pvalue(0.1, 1000, 1000));
        assert!(ks_pvalue(0.5, 1000, 1000) < 1e-6);
    }
}
