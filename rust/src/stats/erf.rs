//! Error function and Gaussian CDF (no libm `erf` in std).
//!
//! Uses the Abramowitz–Stegun 7.1.26-style rational approximation refined
//! by W. J. Cody; |ε| < 1.2e-7 over the real line — far below the
//! statistical noise of any experiment in this repo.

/// erf(x) with absolute error < 1.2e-7.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function.
pub fn erfc(x: f64) -> f64 {
    let z = x.abs();
    let t = 1.0 / (1.0 + 0.5 * z);
    // Numerical Recipes' erfc approximation (Chebyshev fit).
    let ans = t
        * (-z * z - 1.26551223
            + t * (1.00002368
                + t * (0.37409196
                    + t * (0.09678418
                        + t * (-0.18628806
                            + t * (0.27886807
                                + t * (-1.13520398
                                    + t * (1.48851587
                                        + t * (-0.82215223 + t * 0.17087277)))))))))
        .exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x * std::f64::consts::FRAC_1_SQRT_2)
}

/// The paper's Eq. 13 activation probability: Φ(κ·z).
pub fn probit_sigmoid(z: f64, kappa: f64) -> f64 {
    norm_cdf(kappa * z)
}

/// Logistic function (the software activation being emulated).
pub fn logistic(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

/// Inverse standard normal CDF (Acklam's algorithm, |rel ε| < 1.15e-9).
pub fn norm_ppf(p: f64) -> f64 {
    assert!((0.0..=1.0).contains(&p), "p out of range: {p}");
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // (x, erf(x)) reference values from tables.
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (-1.0, -0.8427007929),
        ] {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
    }

    #[test]
    fn cdf_symmetry_and_tails() {
        // The Chebyshev erfc fit has |ε| < 1.2e-7 — tolerances follow.
        assert!((norm_cdf(0.0) - 0.5).abs() < 2e-7);
        for x in [0.3, 1.0, 2.5] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 4e-7);
        }
        assert!(norm_cdf(8.0) > 0.999999);
        assert!(norm_cdf(-8.0) < 1e-6);
    }

    #[test]
    fn probit_approximates_logistic_at_1702() {
        // The paper's operating point: max gap < 0.0095.
        let kappa = 1.0 / 1.702;
        let mut worst: f64 = 0.0;
        let mut z = -6.0;
        while z <= 6.0 {
            worst = worst.max((probit_sigmoid(z, kappa) - logistic(z)).abs());
            z += 0.01;
        }
        assert!(worst < 0.0095, "worst={worst}");
    }

    #[test]
    fn ppf_inverts_cdf() {
        for p in [0.001, 0.025, 0.2, 0.5, 0.9, 0.999] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p}");
        }
    }
}
