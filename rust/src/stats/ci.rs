//! Binomial confidence intervals — the coordinator's early-stopping rule
//! and the accuracy error bars in Fig. 6 both need them.

use super::erf::norm_ppf;

/// Wilson score interval for a binomial proportion.
///
/// Returns `(lo, hi)` for `successes` out of `n` at confidence `conf`
/// (e.g. 0.95).  Robust for small n and extreme p — unlike the normal
/// approximation interval.
pub fn wilson_interval(successes: u64, n: u64, conf: f64) -> (f64, f64) {
    if n == 0 {
        return (0.0, 1.0);
    }
    let z = norm_ppf(0.5 + conf / 2.0);
    let nf = n as f64;
    let p = successes as f64 / nf;
    let z2 = z * z;
    let denom = 1.0 + z2 / nf;
    let center = (p + z2 / (2.0 * nf)) / denom;
    let half = z * (p * (1.0 - p) / nf + z2 / (4.0 * nf * nf)).sqrt() / denom;
    ((center - half).max(0.0), (center + half).min(1.0))
}

/// Is class `lead` statistically ahead of `runner_up` given vote counts?
///
/// Conservative pairwise rule used by the coordinator's early stopper:
/// treat the lead-vs-runner-up votes as a binomial and require the Wilson
/// lower bound of lead/(lead+runner_up) to clear 0.5.
pub fn lead_is_decided(lead_votes: u64, runner_up_votes: u64, conf: f64) -> bool {
    let n = lead_votes + runner_up_votes;
    if n == 0 {
        return false;
    }
    let (lo, _) = wilson_interval(lead_votes, n, conf);
    lo > 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_contains_p_hat() {
        let (lo, hi) = wilson_interval(80, 100, 0.95);
        assert!(lo < 0.8 && 0.8 < hi);
        assert!(lo > 0.70 && hi < 0.88);
    }

    #[test]
    fn degenerate_cases() {
        assert_eq!(wilson_interval(0, 0, 0.95), (0.0, 1.0));
        let (lo, hi) = wilson_interval(0, 10, 0.95);
        assert_eq!(lo, 0.0);
        assert!(hi > 0.0 && hi < 0.4);
        let (lo, hi) = wilson_interval(10, 10, 0.95);
        assert!(lo > 0.6);
        assert_eq!(hi, 1.0);
    }

    #[test]
    fn narrower_with_more_samples() {
        let (lo1, hi1) = wilson_interval(60, 100, 0.95);
        let (lo2, hi2) = wilson_interval(600, 1000, 0.95);
        assert!(hi2 - lo2 < hi1 - lo1);
    }

    #[test]
    fn decided_needs_margin() {
        assert!(!lead_is_decided(3, 2, 0.95));
        assert!(!lead_is_decided(6, 4, 0.95));
        assert!(lead_is_decided(30, 5, 0.95));
        assert!(!lead_is_decided(0, 0, 0.95));
    }

    #[test]
    fn higher_confidence_is_harder() {
        // 14 vs 6 is decided at 90% but not at 99.9%.
        assert!(lead_is_decided(14, 6, 0.90));
        assert!(!lead_is_decided(14, 6, 0.999));
    }
}
