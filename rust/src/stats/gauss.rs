//! Gaussian sampling — Marsaglia–Tsang ziggurat (fast path: one u64, one
//! table lookup, one compare) with a Box–Muller reference implementation
//! for cross-checks.
//!
//! The native engine draws one Gaussian per neuron (comparator noise) per
//! trial — this is the innermost loop of the whole simulator.  §Perf
//! iteration 2 replaced polar Box–Muller (a libm `ln` per sample) with
//! the 256-layer ziggurat: ~97.5% of samples take the rejection-free
//! fast path.

use std::sync::OnceLock;

use super::rng::Rng;

const ZIG_LAYERS: usize = 256;
/// Rightmost ziggurat x (Marsaglia–Tsang, 256 layers).
const ZIG_R: f64 = 3.6541528853610088;
const ZIG_V: f64 = 0.00492867323399; // area per layer

struct ZigTables {
    x: [f64; ZIG_LAYERS + 1],
    y: [f64; ZIG_LAYERS + 1],
}

fn pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp()
}

static ZIG: OnceLock<ZigTables> = OnceLock::new();

fn zig_tables() -> &'static ZigTables {
    ZIG.get_or_init(|| {
        let mut x = [0.0f64; ZIG_LAYERS + 1];
        let mut y = [0.0f64; ZIG_LAYERS + 1];
        x[0] = ZIG_R;
        y[0] = pdf(ZIG_R);
        // x[1] chosen so layer 0 (tail) has area V: V = R·f(R) + tail(R).
        x[1] = ZIG_R;
        y[1] = y[0];
        for i in 2..=ZIG_LAYERS {
            // y_{i} = y_{i-1} + V / x_{i-1}
            y[i] = y[i - 1] + ZIG_V / x[i - 1];
            if y[i] >= 1.0 {
                x[i] = 0.0;
                y[i] = 1.0;
            } else {
                x[i] = (-2.0 * y[i].ln()).sqrt();
            }
        }
        ZigTables { x, y }
    })
}

/// Stateful standard-normal source over an owned [`Rng`].
#[derive(Debug, Clone)]
pub struct GaussianSource {
    rng: Rng,
    spare: Option<f64>,
}

/// One ziggurat sample off `rng`.  The shared core of [`GaussianSource::
/// next`] and the batched [`GaussianSource::fill`] — one function so the
/// two paths stay draw-for-draw identical by construction (the blocked
/// trial kernel's bit-parity contract depends on it).
#[inline(always)]
fn sample_std(rng: &mut Rng, zig: &ZigTables) -> f64 {
    loop {
        let bits = rng.next_u64();
        let i = (bits & 0xFF) as usize; // layer
        let sign = if bits & 0x100 != 0 { 1.0 } else { -1.0 };
        // 53-bit uniform in [0,1).
        let u = (bits >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if i == 0 {
            // Base layer: sample x uniform on [0, V/y1]; accept if
            // under the curve, else sample the tail.
            let x = u * ZIG_V / zig.y[1];
            if x < zig.x[1] {
                return sign * x;
            }
            // Tail beyond R (Marsaglia's method).
            loop {
                let u1 = rng.next_f64_open();
                let u2 = rng.next_f64_open();
                let x = -u1.ln() / ZIG_R;
                if -2.0 * u2.ln() > x * x {
                    return sign * (ZIG_R + x);
                }
            }
        }
        let x = u * zig.x[i];
        if x < zig.x[i + 1] {
            return sign * x; // fully inside the layer — fast path
        }
        // Wedge: accept with probability proportional to the pdf gap.
        let y = zig.y[i] + rng.next_f64() * (zig.y[i + 1] - zig.y[i]);
        if y < pdf(x) {
            return sign * x;
        }
    }
}

impl GaussianSource {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), spare: None }
    }

    pub fn from_rng(rng: Rng) -> Self {
        Self { rng, spare: None }
    }

    /// One standard normal sample (ziggurat).
    #[inline]
    pub fn next(&mut self) -> f64 {
        sample_std(&mut self.rng, zig_tables())
    }

    /// Polar Box–Muller reference sampler (cross-check tests only).
    #[inline]
    pub fn next_boxmuller(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.rng.next_f64() - 1.0;
            let v = 2.0 * self.rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Normal with explicit mean/std.
    #[inline]
    pub fn sample(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next()
    }

    /// Fill a slice with σ-scaled normals — the batched fast path of the
    /// trial-blocked kernel (§Perf iteration 5).  The ziggurat table
    /// pointer is resolved once for the whole slice and the fast-path
    /// sampler inlines straight into this loop, instead of paying the
    /// `OnceLock` load + call per draw.  Draw-for-draw identical to
    /// repeated [`GaussianSource::next`] (pinned by
    /// `fill_matches_next_draw_for_draw`).
    ///
    /// §Perf iteration 6: with a SIMD kernel table dispatched
    /// ([`crate::util::simd::active`]), chunks of
    /// [`crate::util::simd::ZIG_LANES`] samples run *speculatively*: the
    /// RNG is snapshotted (xoshiro256++ state is 32 bytes — a cheap
    /// clone), the chunk's u64s are pre-drawn, and if every lane lands on
    /// a non-base layer the vector kernel evaluates all the rejection-free
    /// accepts at once.  Any base-layer draw or wedge/tail excursion
    /// rewinds the RNG to the snapshot and replays the chunk through the
    /// scalar sampler, so rejection paths consume draws in the scalar
    /// order by construction — the draw-for-draw pin holds bit-exactly.
    /// ~97.5% of draws accept, so ≈82% of 8-lane chunks commit.
    pub fn fill(&mut self, out: &mut [f64], std: f64) {
        let zig = zig_tables();
        let k = crate::util::simd::active();
        if k.isa == crate::util::simd::Isa::Scalar {
            for o in out.iter_mut() {
                *o = std * sample_std(&mut self.rng, zig);
            }
            return;
        }
        const W: usize = crate::util::simd::ZIG_LANES;
        let mut chunks = out.chunks_exact_mut(W);
        'chunk: for chunk in chunks.by_ref() {
            let snapshot = self.rng.clone();
            let mut bits = [0u64; W];
            let mut lo = [0.0f64; W];
            let mut hi = [0.0f64; W];
            for lane in 0..W {
                let b = self.rng.next_u64();
                let i = (b & 0xFF) as usize;
                if i == 0 {
                    // Base layer / tail: bail the whole chunk to scalar.
                    self.rng = snapshot;
                    for o in chunk.iter_mut() {
                        *o = std * sample_std(&mut self.rng, zig);
                    }
                    continue 'chunk;
                }
                bits[lane] = b;
                lo[lane] = zig.x[i];
                hi[lane] = zig.x[i + 1];
            }
            if !(k.zig_fastpath)(&bits, &lo, &hi, std, &mut *chunk) {
                self.rng = snapshot;
                for o in chunk.iter_mut() {
                    *o = std * sample_std(&mut self.rng, zig);
                }
            }
        }
        for o in chunks.into_remainder().iter_mut() {
            *o = std * sample_std(&mut self.rng, zig);
        }
    }

    /// Lognormal sample: exp(N(μ, σ²)) — device programming variation.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next()).exp()
    }

    /// Access the underlying uniform generator.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut g = GaussianSource::new(1);
        let n = 200_000;
        let (mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = g.next();
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        let skew = s3 / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.03, "skew={skew}");
    }

    #[test]
    fn tail_fractions_match_cdf() {
        let mut g = GaussianSource::new(2);
        let n = 200_000;
        let mut beyond1 = 0;
        let mut beyond2 = 0;
        for _ in 0..n {
            let x = g.next();
            if x > 1.0 {
                beyond1 += 1;
            }
            if x > 2.0 {
                beyond2 += 1;
            }
        }
        let f1 = beyond1 as f64 / n as f64;
        let f2 = beyond2 as f64 / n as f64;
        assert!((f1 - 0.158655).abs() < 0.005, "P(X>1)={f1}");
        assert!((f2 - 0.022750).abs() < 0.002, "P(X>2)={f2}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = GaussianSource::new(5);
        let mut b = GaussianSource::new(5);
        for _ in 0..64 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn ziggurat_matches_boxmuller_distribution() {
        // KS test between the ziggurat and the reference sampler.
        let mut a_src = GaussianSource::new(31);
        let mut b_src = GaussianSource::new(32);
        let a: Vec<f64> = (0..20_000).map(|_| a_src.next()).collect();
        let b: Vec<f64> = (0..20_000).map(|_| b_src.next_boxmuller()).collect();
        assert!(
            crate::stats::ks::same_distribution(&a, &b, 0.01),
            "ziggurat and Box–Muller disagree"
        );
    }

    #[test]
    fn ziggurat_deep_tail_present() {
        // |x| > 3.654 (the ziggurat R) must still occur at the right rate
        // (~2.6e-4): the tail path works.
        let mut g = GaussianSource::new(33);
        let n = 400_000;
        let beyond = (0..n).filter(|_| g.next().abs() > ZIG_R).count();
        let f = beyond as f64 / n as f64;
        let want = 2.0 * (1.0 - crate::stats::erf::norm_cdf(ZIG_R));
        assert!(f > want * 0.5 && f < want * 1.8, "tail fraction {f} vs {want}");
    }

    #[test]
    fn fill_matches_next_draw_for_draw() {
        // The blocked kernel batches its noise through `fill`; the scalar
        // path draws through `next`.  Bit-parity of the two kernels
        // requires the samplers to agree on every single draw — including
        // σ scaling, wedge rejections and deep-tail samples.
        let mut batched = GaussianSource::new(0xF111);
        let mut scalar = GaussianSource::new(0xF111);
        let mut buf = vec![0.0f64; 4096];
        batched.fill(&mut buf, 1.702);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, 1.702 * scalar.next(), "draw {i} diverged");
        }
        // The streams stay aligned after the batch.
        assert_eq!(batched.next(), scalar.next());
        // σ = 0 degenerates cleanly (still consumes the draws).
        batched.fill(&mut buf[..8], 0.0);
        assert!(buf[..8].iter().all(|&v| v == 0.0));
        for _ in 0..8 {
            scalar.next();
        }
        assert_eq!(batched.next(), scalar.next());
    }

    #[test]
    fn fill_matches_next_at_every_chunk_shape() {
        // Lengths straddling the speculative SIMD chunk width (8):
        // shorter, exact, one-over, and long runs with a scalar tail —
        // every shape must stay draw-for-draw identical to `next`,
        // including chunks that bail to the scalar replay path.
        for &len in &[1usize, 7, 8, 9, 37, 256] {
            let seed = 0xABC0 + len as u64;
            let mut batched = GaussianSource::new(seed);
            let mut scalar = GaussianSource::new(seed);
            let mut buf = vec![0.0f64; len];
            batched.fill(&mut buf, 1.702);
            for (i, &v) in buf.iter().enumerate() {
                assert_eq!(v, 1.702 * scalar.next(), "len {len} draw {i}");
            }
            assert_eq!(batched.next(), scalar.next(), "len {len} stream misaligned");
        }
    }

    #[test]
    fn lognormal_median() {
        let mut g = GaussianSource::new(7);
        let n = 50_000;
        let mut below = 0;
        for _ in 0..n {
            if g.lognormal(0.0, 0.5) < 1.0 {
                below += 1;
            }
        }
        // Median of lognormal(0, σ) is exp(0) = 1.
        assert!((below as f64 / n as f64 - 0.5).abs() < 0.01);
    }
}
