//! Streaming summary statistics (Welford) — used by benches and metrics.

/// Online mean/variance/min/max accumulator.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        self.std() / (self.n as f64).sqrt()
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.6} std={:.6} min={:.6} max={:.6}",
            self.n,
            self.mean(),
            self.std(),
            self.min,
            self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut all = Summary::new();
        xs.iter().for_each(|&x| all.add(x));
        let mut a = Summary::new();
        let mut b = Summary::new();
        xs[..37].iter().for_each(|&x| a.add(x));
        xs[37..].iter().for_each(|&x| b.add(x));
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.var() - all.var()).abs() < 1e-10);
    }

    #[test]
    fn empty_behaviour() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.var().is_nan());
        assert_eq!(s.count(), 0);
    }
}
