//! xoshiro256++ PRNG seeded via SplitMix64 (Blackman & Vigna).
//!
//! Fast, high-quality, and tiny — the simulator draws billions of noise
//! samples per Fig. 6 sweep, so this sits on the native hot path.

/// xoshiro256++ generator state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically (any u64, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Derive an independent stream (for per-worker / per-column RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe for log().
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method.
        let mut m = (self.next_u64() as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut u = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn open_interval_never_zero() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.next_f64_open() > 0.0);
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = Rng::new(5);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[1] as f64 / 30_000.0 - 0.3).abs() < 0.02);
        assert!((counts[2] as f64 / 30_000.0 - 0.6).abs() < 0.02);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
