//! Statistics substrate (DESIGN.md §4.1).
//!
//! The offline vendor set has no `rand`/`statrs`, so the simulator's
//! randomness and special functions live here: a counter-free xoshiro256++
//! PRNG, Gaussian sampling, `erf`, histograms, summaries and binomial
//! confidence intervals.  Everything is deterministic given a seed —
//! figure regeneration is reproducible bit-for-bit.

pub mod ci;
pub mod erf;
pub mod gauss;
pub mod hist;
pub mod ks;
pub mod rng;
pub mod summary;

pub use ci::wilson_interval;
pub use erf::{erf, erfc, norm_cdf, probit_sigmoid};
pub use gauss::GaussianSource;
pub use hist::Histogram;
pub use rng::Rng;
pub use summary::Summary;
