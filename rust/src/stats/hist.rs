//! Fixed-bin histogram (figure harnesses: noise distributions, traces).

/// Uniform-bin histogram over [lo, hi); out-of-range samples clamp to the
/// edge bins (the figure code wants totals to be conserved).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], total: 0 }
    }

    #[inline]
    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin centers.
    pub fn centers(&self) -> Vec<f64> {
        let n = self.counts.len();
        let w = (self.hi - self.lo) / n as f64;
        (0..n).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Probability density estimate per bin.
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let norm = (self.total.max(1)) as f64 * w;
        self.counts.iter().map(|&c| c as f64 / norm).collect()
    }

    /// Empirical mean from binned data.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        self.centers()
            .iter()
            .zip(&self.counts)
            .map(|(c, &n)| c * n as f64)
            .sum::<f64>()
            / self.total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_totals() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert_eq!(h.total, 10);
        assert!(h.counts.iter().all(|&c| c == 1));
    }

    #[test]
    fn clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-5.0);
        h.add(7.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
        assert_eq!(h.total, 2);
    }

    #[test]
    fn density_integrates_to_one() {
        let mut h = Histogram::new(-4.0, 4.0, 64);
        let mut g = crate::stats::GaussianSource::new(3);
        for _ in 0..50_000 {
            h.add(g.next());
        }
        let w = 8.0 / 64.0;
        let integral: f64 = h.density().iter().map(|d| d * w).sum();
        assert!((integral - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gaussian_histogram_mean() {
        let mut h = Histogram::new(-6.0, 6.0, 128);
        let mut g = crate::stats::GaussianSource::new(4);
        for _ in 0..100_000 {
            h.add(g.sample(1.5, 0.5));
        }
        assert!((h.mean() - 1.5).abs() < 0.01);
    }
}
