//! Explicit SIMD kernels with one-time runtime dispatch (§Perf
//! iteration 6).
//!
//! The trial-blocked bit-packed kernel (§Perf iteration 5) made the
//! inner column-add of `nn::forward::affine_bits_block` the hottest loop
//! of the whole simulator — and left its vectorization to compiler luck.
//! This module makes it explicit: arch-gated intrinsic kernels
//! (x86_64 AVX2 / SSE2, aarch64 NEON) behind a [`Kernels`] table of
//! plain function pointers, selected **once** per process by
//! [`active`] from runtime CPU feature detection, with a portable
//! unrolled-scalar fallback and a `RACA_NO_SIMD=1` escape hatch that
//! forces the fallback on any machine (set it to diagnose a suspected
//! codegen issue, or to bench the scalar floor).
//!
//! ## The columns-lane parity argument
//!
//! Every kernel here is held to the §Perf-5 contract: the dispatched
//! path must be **bit-identical** to the scalar reference.  That is only
//! possible because each kernel vectorizes across the *columns* (output
//! elements) dimension and never reassociates a reduction:
//!
//! * [`Kernels::add_assign_f32`] — `out[j] += row[j]`.  The blocked
//!   matmul accumulates weight rows into per-trial accumulators in
//!   ascending row order; lanes span columns `j`, so each `out[j]` sees
//!   the exact scalar sequence of f32 additions, just eight columns per
//!   instruction.  IEEE-754 addition is deterministic per element, so
//!   the accumulators are bit-identical.
//! * [`Kernels::center_f32`] — `out[j] = (z[j] - mean) as f64 - theta`.
//!   Pure elementwise map (f32 subtract, exact widen, f64 subtract) —
//!   the per-row mean itself stays a scalar ordered sum in the caller.
//! * [`Kernels::race_step`] — one WTA race step.  The scalar loop scans
//!   columns ascending keeping a strict-`>` running best, i.e. it
//!   returns the *first* index attaining the maximum, provided that
//!   maximum is `> 0`.  The SIMD kernel computes the same f64 sums
//!   `v[j] = centered[j] + noise[j]` (elementwise, no reassociation),
//!   takes a lane-wise max (max is associative and commutative over
//!   totally-ordered finite floats — no NaNs reach this kernel), and
//!   then rescans for the first `v[j] ==` that max: the identical
//!   winner.
//! * [`Kernels::zig_fastpath`] — the speculative batched ziggurat fast
//!   path (`stats::gauss::GaussianSource::fill`).  For a chunk of
//!   [`ZIG_LANES`] pre-drawn `u64`s whose layer index is non-zero, it
//!   computes `x = u·x_i` and the accept test `x < x_{i+1}` lane-wise —
//!   the exact fast-path arithmetic of the scalar sampler (`u` is a
//!   power-of-two scaling of a ≤53-bit integer, so every intermediate
//!   is exact) — and commits the chunk only when **all** lanes accept.
//!   Any base-layer draw, wedge test, or tail excursion makes the
//!   caller rewind its RNG and replay the chunk through the scalar
//!   sampler, so rejection paths consume draws in the scalar order by
//!   construction.
//!
//! `rust/tests/simd.rs` pins every available variant against the scalar
//! reference bit-for-bit (odd widths, tails, ties), and CI runs the
//! whole test suite a second time under `RACA_NO_SIMD=1` so the
//! fallback cannot rot.

use std::sync::OnceLock;

/// Samples per speculative ziggurat chunk (see [`Kernels::zig_fastpath`]).
pub const ZIG_LANES: usize = 8;

/// 53-bit-uniform scale: `1 / 2^53` (must match `stats::rng::Rng::next_f64`).
const U53: f64 = 1.0 / (1u64 << 53) as f64;

/// Instruction set selected by the dispatcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// x86_64 AVX2 (8×f32 / 4×f64 lanes).
    Avx2,
    /// x86_64 SSE2 baseline (4×f32 / 2×f64 lanes).
    Sse2,
    /// aarch64 NEON (4×f32 / 2×f64 lanes).
    Neon,
    /// Portable unrolled-scalar fallback.
    Scalar,
}

impl Isa {
    /// Stable lowercase name, logged in bench reports (`simd_isa`).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Avx2 => "avx2",
            Isa::Sse2 => "sse2",
            Isa::Neon => "neon",
            Isa::Scalar => "scalar",
        }
    }
}

/// One coherent set of kernels for a single ISA.  All four entries are
/// plain `fn` pointers so the hot loops pay one indirect call per
/// row/step/chunk — never a per-element dispatch.
pub struct Kernels {
    pub isa: Isa,
    /// `out[j] += row[j]` — the blocked matmul's inner column-add.
    pub add_assign_f32: fn(&mut [f32], &[f32]),
    /// `out[j] = (z[j] - mean) as f64 - theta` — WTA centering prepass.
    pub center_f32: fn(&[f32], f32, f64, &mut [f64]),
    /// One WTA race step over `v[j] = centered[j] + noise[j]`: index of
    /// the first maximum if it is `> 0`, else `-1`.
    pub race_step: fn(&[f64], &[f64]) -> i32,
    /// Speculative ziggurat chunk: `(bits, x_i, x_{i+1}, std, out)`.
    /// Returns `true` (and writes `out[..ZIG_LANES]`) iff every lane
    /// takes the rejection-free fast path.
    pub zig_fastpath: fn(&[u64; ZIG_LANES], &[f64; ZIG_LANES], &[f64; ZIG_LANES], f64, &mut [f64]) -> bool,
}

impl Kernels {
    /// Shorthand for `self.isa.name()`.
    pub fn name(&self) -> &'static str {
        self.isa.name()
    }
}

/// `RACA_NO_SIMD` set to anything but empty/`0` forces the scalar table.
fn fallback_forced() -> bool {
    std::env::var("RACA_NO_SIMD").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

// unreachable_code: on x86_64/aarch64 a cfg-gated `return` always fires
// first, leaving the scalar tail for every other target.
#[allow(unreachable_code)]
fn detect() -> &'static Kernels {
    if fallback_forced() {
        return &SCALAR;
    }
    #[cfg(target_arch = "x86_64")]
    return if std::arch::is_x86_feature_detected!("avx2") { &x86::AVX2 } else { &x86::SSE2 };
    #[cfg(target_arch = "aarch64")]
    return &arm::NEON;
    &SCALAR
}

/// The process-wide kernel table: detected once on first use (any
/// thread), identical ever after — callers may cache the reference.
pub fn active() -> &'static Kernels {
    static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();
    ACTIVE.get_or_init(detect)
}

/// Every kernel table the *current* CPU can execute (scalar always
/// included, detection-gated ISAs after it) — the test harness runs the
/// full parity matrix over all of them regardless of which one
/// [`active`] picked.
pub fn variants() -> Vec<&'static Kernels> {
    #[allow(unused_mut)]
    let mut v: Vec<&'static Kernels> = vec![&SCALAR];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(&x86::SSE2);
        if std::arch::is_x86_feature_detected!("avx2") {
            v.push(&x86::AVX2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    v.push(&arm::NEON);
    v
}

// --------------------------------------------------------------------------
// Portable unrolled-scalar fallback (also the parity reference in tests).

static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    add_assign_f32: add_assign_f32_scalar,
    center_f32: center_f32_scalar,
    race_step: race_step_scalar,
    zig_fastpath: zig_fastpath_scalar,
};

fn add_assign_f32_scalar(out: &mut [f32], row: &[f32]) {
    debug_assert_eq!(out.len(), row.len());
    // 4-way unroll: enough for the compiler to keep four independent adds
    // in flight without asking it to discover the loop shape on its own.
    let mut o4 = out.chunks_exact_mut(4);
    let mut r4 = row.chunks_exact(4);
    for (o, r) in o4.by_ref().zip(r4.by_ref()) {
        o[0] += r[0];
        o[1] += r[1];
        o[2] += r[2];
        o[3] += r[3];
    }
    for (o, &r) in o4.into_remainder().iter_mut().zip(r4.remainder()) {
        *o += r;
    }
}

fn center_f32_scalar(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
    debug_assert_eq!(z.len(), out.len());
    for (o, &zj) in out.iter_mut().zip(z) {
        *o = (zj - mean) as f64 - theta;
    }
}

fn race_step_scalar(centered: &[f64], noise: &[f64]) -> i32 {
    debug_assert_eq!(centered.len(), noise.len());
    let mut winner = -1i32;
    let mut best = f64::NEG_INFINITY;
    for (j, (&cj, &nj)) in centered.iter().zip(noise).enumerate() {
        let v = cj + nj;
        if v > 0.0 && v > best {
            best = v;
            winner = j as i32;
        }
    }
    winner
}

fn zig_fastpath_scalar(
    bits: &[u64; ZIG_LANES],
    lo: &[f64; ZIG_LANES],
    hi: &[f64; ZIG_LANES],
    std: f64,
    out: &mut [f64],
) -> bool {
    debug_assert!(out.len() >= ZIG_LANES);
    let mut x = [0.0f64; ZIG_LANES];
    for k in 0..ZIG_LANES {
        // Exactly the scalar sampler's fast path: u is (bits >> 11)
        // scaled by 2^-53 (both steps exact), x = u·x_i, accept x < x_{i+1}.
        let u = (bits[k] >> 11) as f64 * U53;
        x[k] = u * lo[k];
        if x[k] >= hi[k] {
            return false;
        }
    }
    for k in 0..ZIG_LANES {
        // sign·(std·x) ≡ std·(sign·x): negation is exact, so the product
        // matches the scalar `std * (sign * x)` bit-for-bit.
        let v = std * x[k];
        out[k] = if bits[k] & 0x100 != 0 { v } else { -v };
    }
    true
}

// --------------------------------------------------------------------------
// x86_64: AVX2 (8×f32/4×f64) and the SSE2 baseline (4×f32/2×f64).

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{Isa, Kernels, U53, ZIG_LANES};
    use std::arch::x86_64::*;

    pub(super) static AVX2: Kernels = Kernels {
        isa: Isa::Avx2,
        add_assign_f32: add_assign_f32_avx2,
        center_f32: center_f32_avx2,
        race_step: race_step_avx2,
        zig_fastpath: zig_fastpath_avx2,
    };

    pub(super) static SSE2: Kernels = Kernels {
        isa: Isa::Sse2,
        add_assign_f32: add_assign_f32_sse2,
        center_f32: center_f32_sse2,
        race_step: race_step_sse2,
        zig_fastpath: zig_fastpath_sse2,
    };

    // The safe wrappers below are only ever reachable through a Kernels
    // table that `detect`/`variants` hands out after the matching CPUID
    // check (SSE2 is the x86_64 baseline), so the target_feature calls
    // are sound.

    fn add_assign_f32_avx2(out: &mut [f32], row: &[f32]) {
        unsafe { add_assign_f32_avx2_impl(out, row) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn add_assign_f32_avx2_impl(out: &mut [f32], row: &[f32]) {
        debug_assert_eq!(out.len(), row.len());
        let n = out.len();
        let op = out.as_mut_ptr();
        let rp = row.as_ptr();
        let mut j = 0usize;
        while j + 16 <= n {
            let a0 = _mm256_loadu_ps(op.add(j));
            let a1 = _mm256_loadu_ps(op.add(j + 8));
            let b0 = _mm256_loadu_ps(rp.add(j));
            let b1 = _mm256_loadu_ps(rp.add(j + 8));
            _mm256_storeu_ps(op.add(j), _mm256_add_ps(a0, b0));
            _mm256_storeu_ps(op.add(j + 8), _mm256_add_ps(a1, b1));
            j += 16;
        }
        if j + 8 <= n {
            let a = _mm256_loadu_ps(op.add(j));
            let b = _mm256_loadu_ps(rp.add(j));
            _mm256_storeu_ps(op.add(j), _mm256_add_ps(a, b));
            j += 8;
        }
        while j < n {
            *op.add(j) += *rp.add(j);
            j += 1;
        }
    }

    fn add_assign_f32_sse2(out: &mut [f32], row: &[f32]) {
        unsafe { add_assign_f32_sse2_impl(out, row) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn add_assign_f32_sse2_impl(out: &mut [f32], row: &[f32]) {
        debug_assert_eq!(out.len(), row.len());
        let n = out.len();
        let op = out.as_mut_ptr();
        let rp = row.as_ptr();
        let mut j = 0usize;
        while j + 4 <= n {
            let a = _mm_loadu_ps(op.add(j));
            let b = _mm_loadu_ps(rp.add(j));
            _mm_storeu_ps(op.add(j), _mm_add_ps(a, b));
            j += 4;
        }
        while j < n {
            *op.add(j) += *rp.add(j);
            j += 1;
        }
    }

    fn center_f32_avx2(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        unsafe { center_f32_avx2_impl(z, mean, theta, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn center_f32_avx2_impl(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        debug_assert_eq!(z.len(), out.len());
        let n = z.len();
        let m = _mm256_set1_ps(mean);
        let th = _mm256_set1_pd(theta);
        let mut j = 0usize;
        while j + 8 <= n {
            let d = _mm256_sub_ps(_mm256_loadu_ps(z.as_ptr().add(j)), m);
            let lo = _mm256_cvtps_pd(_mm256_castps256_ps128(d));
            let hi = _mm256_cvtps_pd(_mm256_extractf128_ps(d, 1));
            _mm256_storeu_pd(out.as_mut_ptr().add(j), _mm256_sub_pd(lo, th));
            _mm256_storeu_pd(out.as_mut_ptr().add(j + 4), _mm256_sub_pd(hi, th));
            j += 8;
        }
        while j < n {
            *out.get_unchecked_mut(j) = (*z.get_unchecked(j) - mean) as f64 - theta;
            j += 1;
        }
    }

    fn center_f32_sse2(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        unsafe { center_f32_sse2_impl(z, mean, theta, out) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn center_f32_sse2_impl(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        debug_assert_eq!(z.len(), out.len());
        let n = z.len();
        let m = _mm_set1_ps(mean);
        let th = _mm_set1_pd(theta);
        let mut j = 0usize;
        while j + 4 <= n {
            let d = _mm_sub_ps(_mm_loadu_ps(z.as_ptr().add(j)), m);
            let lo = _mm_cvtps_pd(d);
            let hi = _mm_cvtps_pd(_mm_movehl_ps(d, d));
            _mm_storeu_pd(out.as_mut_ptr().add(j), _mm_sub_pd(lo, th));
            _mm_storeu_pd(out.as_mut_ptr().add(j + 2), _mm_sub_pd(hi, th));
            j += 4;
        }
        while j < n {
            *out.get_unchecked_mut(j) = (*z.get_unchecked(j) - mean) as f64 - theta;
            j += 1;
        }
    }

    fn race_step_avx2(centered: &[f64], noise: &[f64]) -> i32 {
        unsafe { race_step_avx2_impl(centered, noise) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn race_step_avx2_impl(centered: &[f64], noise: &[f64]) -> i32 {
        debug_assert_eq!(centered.len(), noise.len());
        let n = centered.len();
        let cp = centered.as_ptr();
        let np = noise.as_ptr();
        let mut mx = _mm256_set1_pd(f64::NEG_INFINITY);
        let mut j = 0usize;
        while j + 4 <= n {
            let v = _mm256_add_pd(_mm256_loadu_pd(cp.add(j)), _mm256_loadu_pd(np.add(j)));
            mx = _mm256_max_pd(mx, v);
            j += 4;
        }
        let hi = _mm256_extractf128_pd(mx, 1);
        let lo = _mm256_castpd256_pd128(mx);
        let m2 = _mm_max_pd(lo, hi);
        let m1 = _mm_max_sd(m2, _mm_unpackhi_pd(m2, m2));
        let mut best = _mm_cvtsd_f64(m1);
        while j < n {
            let v = *cp.add(j) + *np.add(j);
            if v > best {
                best = v;
            }
            j += 1;
        }
        super::first_at_max(centered, noise, best)
    }

    fn race_step_sse2(centered: &[f64], noise: &[f64]) -> i32 {
        unsafe { race_step_sse2_impl(centered, noise) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn race_step_sse2_impl(centered: &[f64], noise: &[f64]) -> i32 {
        debug_assert_eq!(centered.len(), noise.len());
        let n = centered.len();
        let cp = centered.as_ptr();
        let np = noise.as_ptr();
        let mut mx = _mm_set1_pd(f64::NEG_INFINITY);
        let mut j = 0usize;
        while j + 2 <= n {
            let v = _mm_add_pd(_mm_loadu_pd(cp.add(j)), _mm_loadu_pd(np.add(j)));
            mx = _mm_max_pd(mx, v);
            j += 2;
        }
        let m1 = _mm_max_sd(mx, _mm_unpackhi_pd(mx, mx));
        let mut best = _mm_cvtsd_f64(m1);
        while j < n {
            let v = *cp.add(j) + *np.add(j);
            if v > best {
                best = v;
            }
            j += 1;
        }
        super::first_at_max(centered, noise, best)
    }

    fn zig_fastpath_avx2(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        unsafe { zig_fastpath_avx2_impl(bits, lo, hi, std, out) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn zig_fastpath_avx2_impl(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        debug_assert!(out.len() >= ZIG_LANES);
        let (u, sx) = super::zig_prep(bits);
        let c = _mm256_set1_pd(U53);
        let s = _mm256_set1_pd(std);
        for h in 0..2 {
            let uu = _mm256_mul_pd(_mm256_loadu_pd(u.as_ptr().add(4 * h)), c);
            let x = _mm256_mul_pd(uu, _mm256_loadu_pd(lo.as_ptr().add(4 * h)));
            let ok = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_loadu_pd(hi.as_ptr().add(4 * h)));
            if _mm256_movemask_pd(ok) != 0xF {
                return false;
            }
            let flip = _mm256_loadu_si256(sx.as_ptr().add(4 * h) as *const __m256i);
            let v = _mm256_xor_pd(_mm256_mul_pd(s, x), _mm256_castsi256_pd(flip));
            _mm256_storeu_pd(out.as_mut_ptr().add(4 * h), v);
        }
        true
    }

    fn zig_fastpath_sse2(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        unsafe { zig_fastpath_sse2_impl(bits, lo, hi, std, out) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn zig_fastpath_sse2_impl(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        debug_assert!(out.len() >= ZIG_LANES);
        let (u, sx) = super::zig_prep(bits);
        let c = _mm_set1_pd(U53);
        let s = _mm_set1_pd(std);
        for h in 0..4 {
            let uu = _mm_mul_pd(_mm_loadu_pd(u.as_ptr().add(2 * h)), c);
            let x = _mm_mul_pd(uu, _mm_loadu_pd(lo.as_ptr().add(2 * h)));
            let ok = _mm_cmplt_pd(x, _mm_loadu_pd(hi.as_ptr().add(2 * h)));
            if _mm_movemask_pd(ok) != 0x3 {
                return false;
            }
            let flip = _mm_loadu_si128(sx.as_ptr().add(2 * h) as *const __m128i);
            let v = _mm_xor_pd(_mm_mul_pd(s, x), _mm_castsi128_pd(flip));
            _mm_storeu_pd(out.as_mut_ptr().add(2 * h), v);
        }
        true
    }
}

// --------------------------------------------------------------------------
// aarch64: NEON (4×f32/2×f64, baseline on every aarch64 target).

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::{Isa, Kernels, U53, ZIG_LANES};
    use std::arch::aarch64::*;

    pub(super) static NEON: Kernels = Kernels {
        isa: Isa::Neon,
        add_assign_f32: add_assign_f32_neon,
        center_f32: center_f32_neon,
        race_step: race_step_neon,
        zig_fastpath: zig_fastpath_neon,
    };

    // NEON is part of the aarch64 baseline, so the wrappers are sound on
    // every CPU this module compiles for.

    fn add_assign_f32_neon(out: &mut [f32], row: &[f32]) {
        unsafe { add_assign_f32_neon_impl(out, row) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn add_assign_f32_neon_impl(out: &mut [f32], row: &[f32]) {
        debug_assert_eq!(out.len(), row.len());
        let n = out.len();
        let op = out.as_mut_ptr();
        let rp = row.as_ptr();
        let mut j = 0usize;
        while j + 8 <= n {
            let a0 = vld1q_f32(op.add(j));
            let a1 = vld1q_f32(op.add(j + 4));
            let b0 = vld1q_f32(rp.add(j));
            let b1 = vld1q_f32(rp.add(j + 4));
            vst1q_f32(op.add(j), vaddq_f32(a0, b0));
            vst1q_f32(op.add(j + 4), vaddq_f32(a1, b1));
            j += 8;
        }
        if j + 4 <= n {
            vst1q_f32(op.add(j), vaddq_f32(vld1q_f32(op.add(j)), vld1q_f32(rp.add(j))));
            j += 4;
        }
        while j < n {
            *op.add(j) += *rp.add(j);
            j += 1;
        }
    }

    fn center_f32_neon(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        unsafe { center_f32_neon_impl(z, mean, theta, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn center_f32_neon_impl(z: &[f32], mean: f32, theta: f64, out: &mut [f64]) {
        debug_assert_eq!(z.len(), out.len());
        let n = z.len();
        let m = vdupq_n_f32(mean);
        let th = vdupq_n_f64(theta);
        let mut j = 0usize;
        while j + 4 <= n {
            let d = vsubq_f32(vld1q_f32(z.as_ptr().add(j)), m);
            let lo = vcvt_f64_f32(vget_low_f32(d));
            let hi = vcvt_high_f64_f32(d);
            vst1q_f64(out.as_mut_ptr().add(j), vsubq_f64(lo, th));
            vst1q_f64(out.as_mut_ptr().add(j + 2), vsubq_f64(hi, th));
            j += 4;
        }
        while j < n {
            *out.get_unchecked_mut(j) = (*z.get_unchecked(j) - mean) as f64 - theta;
            j += 1;
        }
    }

    fn race_step_neon(centered: &[f64], noise: &[f64]) -> i32 {
        unsafe { race_step_neon_impl(centered, noise) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn race_step_neon_impl(centered: &[f64], noise: &[f64]) -> i32 {
        debug_assert_eq!(centered.len(), noise.len());
        let n = centered.len();
        let cp = centered.as_ptr();
        let np = noise.as_ptr();
        let mut mx = vdupq_n_f64(f64::NEG_INFINITY);
        let mut j = 0usize;
        while j + 2 <= n {
            let v = vaddq_f64(vld1q_f64(cp.add(j)), vld1q_f64(np.add(j)));
            mx = vmaxq_f64(mx, v);
            j += 2;
        }
        let mut best = vmaxvq_f64(mx);
        while j < n {
            let v = *cp.add(j) + *np.add(j);
            if v > best {
                best = v;
            }
            j += 1;
        }
        super::first_at_max(centered, noise, best)
    }

    fn zig_fastpath_neon(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        unsafe { zig_fastpath_neon_impl(bits, lo, hi, std, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn zig_fastpath_neon_impl(
        bits: &[u64; ZIG_LANES],
        lo: &[f64; ZIG_LANES],
        hi: &[f64; ZIG_LANES],
        std: f64,
        out: &mut [f64],
    ) -> bool {
        debug_assert!(out.len() >= ZIG_LANES);
        let (u, sx) = super::zig_prep(bits);
        let c = vdupq_n_f64(U53);
        let s = vdupq_n_f64(std);
        for h in 0..4 {
            let uu = vmulq_f64(vld1q_f64(u.as_ptr().add(2 * h)), c);
            let x = vmulq_f64(uu, vld1q_f64(lo.as_ptr().add(2 * h)));
            let ok = vcltq_f64(x, vld1q_f64(hi.as_ptr().add(2 * h)));
            if vgetq_lane_u64(ok, 0) == 0 || vgetq_lane_u64(ok, 1) == 0 {
                return false;
            }
            let v = veorq_u64(
                vreinterpretq_u64_f64(vmulq_f64(s, x)),
                vld1q_u64(sx.as_ptr().add(2 * h)),
            );
            vst1q_f64(out.as_mut_ptr().add(2 * h), vreinterpretq_f64_u64(v));
        }
        true
    }
}

// --------------------------------------------------------------------------
// Shared helpers of the arch modules.

/// First index whose race value equals the (already computed) maximum —
/// the scalar scan's winner — or -1 when the maximum never cleared zero.
/// f64 addition is deterministic, so recomputing `c + n` here reproduces
/// the SIMD lanes' values exactly.
#[inline]
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")), allow(dead_code))]
fn first_at_max(centered: &[f64], noise: &[f64], best: f64) -> i32 {
    if !(best > 0.0) {
        return -1;
    }
    for (j, (&cj, &nj)) in centered.iter().zip(noise).enumerate() {
        if cj + nj == best {
            return j as i32;
        }
    }
    debug_assert!(false, "race maximum not found on rescan");
    -1
}

/// Per-lane prep of a speculative ziggurat chunk: the 53-bit uniform
/// numerator as f64 (exact — it is < 2^53) and the sign-flip mask
/// (`bits & 0x100` clear means negative in the scalar sampler, applied
/// as an exact IEEE sign-bit XOR).
#[inline]
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")), allow(dead_code))]
fn zig_prep(bits: &[u64; ZIG_LANES]) -> ([f64; ZIG_LANES], [u64; ZIG_LANES]) {
    let mut u = [0.0f64; ZIG_LANES];
    let mut sx = [0u64; ZIG_LANES];
    for k in 0..ZIG_LANES {
        u[k] = (bits[k] >> 11) as f64;
        sx[k] = if bits[k] & 0x100 != 0 { 0 } else { 1u64 << 63 };
    }
    (u, sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_is_stable_and_named() {
        let a = active();
        let b = active();
        assert!(std::ptr::eq(a, b), "dispatch must resolve once");
        assert!(["avx2", "sse2", "neon", "scalar"].contains(&a.name()));
    }

    #[test]
    fn variants_always_lead_with_scalar() {
        let v = variants();
        assert_eq!(v[0].isa, Isa::Scalar);
        #[cfg(target_arch = "x86_64")]
        assert!(v.iter().any(|k| k.isa == Isa::Sse2), "SSE2 is the x86_64 baseline");
    }

    #[test]
    fn scalar_race_step_picks_first_strict_maximum() {
        // Ties resolve to the earliest index; non-positive maxima abstain.
        assert_eq!(race_step_scalar(&[1.0, 1.0], &[0.0, 0.0]), 0);
        assert_eq!(race_step_scalar(&[-1.0, -2.0], &[0.5, 0.5]), -1);
        assert_eq!(race_step_scalar(&[-1.0, 2.0, 3.0, 3.0], &[0.0; 4]), 2);
        assert_eq!(race_step_scalar(&[0.0], &[0.0]), -1, "exactly zero never wins");
    }
}
