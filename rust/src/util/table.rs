//! Plain-text table / CSV emission for the figure & benchmark harnesses.
//!
//! Every `raca figN`/`raca table1` run prints a human-readable table that
//! mirrors the paper's series and writes a machine-readable CSV next to it
//! under `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Column-aligned text table with a CSV twin.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Write the CSV twin (comma-separated; cells are pre-formatted).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }

    /// Print to stdout and save the CSV under `results/<name>.csv`.
    pub fn emit(&self, results_dir: &Path, name: &str) -> std::io::Result<()> {
        println!("{}", self.render());
        let path = results_dir.join(format!("{name}.csv"));
        self.write_csv(&path)?;
        println!("[csv] {}", path.display());
        Ok(())
    }
}

/// Format a float with engineering-friendly precision.
pub fn fmt_g(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e4 || x.abs() < 1e-3 {
        format!("{x:.4e}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("333"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_g_ranges() {
        assert_eq!(fmt_g(0.0), "0");
        assert!(fmt_g(12345.0).contains('e'));
        assert!(fmt_g(0.25).starts_with("0.25"));
    }
}
