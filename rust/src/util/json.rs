//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar; numbers are f64 (adequate for the
//! manifest/weights metadata this repo reads — u64 request ids travel as
//! decimal strings on the wire, see [`crate::serve::net::wire`]).  Also
//! home of the length-prefixed frame reader/writer the serving wire layer
//! streams JSON values over ([`read_frame`]/[`write_frame`]).
//! Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;
use std::io::{self, Read, Write};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.path(&["design_point", "sigma_z"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON from our own tools never
                            // emits them; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run.
                    let start = self.i - 1;
                    while let Some(n) = self.peek() {
                        if n == b'"' || n == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---------------------------------------------------------------------------
// Length-prefixed JSON frames (the serve::net wire format)
// ---------------------------------------------------------------------------

/// Hard cap on a single frame's payload.  A length prefix beyond this is
/// treated as a corrupt (or hostile) stream instead of an allocation
/// request; a full 784-pixel request frame is ~20 KiB, so 16 MiB leaves
/// three orders of magnitude of headroom.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Write `j` as one frame: a 4-byte big-endian payload length, then the
/// compact JSON bytes.  Flushes, so a frame is on the wire when this
/// returns.
pub fn write_frame<W: Write>(w: &mut W, j: &Json) -> io::Result<()> {
    let payload = j.to_string();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("refusing to write a {}-byte frame (cap {MAX_FRAME_BYTES})", bytes.len()),
        ));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one frame off a byte stream.  `Ok(None)` on a clean EOF at a
/// frame boundary (the peer hung up between messages); EOF inside a
/// frame, an oversized length prefix, or a payload that is not valid
/// JSON all surface as `InvalidData` errors — the caller should drop the
/// connection, not retry.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Json>> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "stream ended inside a frame header",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let n = u32::from_be_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds the {MAX_FRAME_BYTES}-byte cap"),
        ));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            io::Error::new(io::ErrorKind::InvalidData, "stream ended inside a frame payload")
        } else {
            e
        }
    })?;
    let text = std::str::from_utf8(&buf)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame payload is not UTF-8"))?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("bad frame payload: {e}")))
}

/// Convenience builders used by the figure/CSV writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\""}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["b", "d"]).unwrap().as_bool(), Some(true));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(j.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn number_forms() {
        for (s, v) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn frames_round_trip_back_to_back() {
        let a = obj(vec![("x", num(1.5)), ("s", Json::Str("hé\"llo".into()))]);
        let b = Json::Arr(vec![Json::Null, Json::Bool(true)]);
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(a));
        assert_eq!(read_frame(&mut r).unwrap(), Some(b));
        // Clean EOF at the frame boundary.
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &num(7.0)).unwrap();
        // EOF inside the header.
        let mut r = &buf[..2];
        assert!(read_frame(&mut r).is_err());
        // EOF inside the payload.
        let mut r = &buf[..buf.len() - 1];
        assert!(read_frame(&mut r).is_err());
        // Length prefix beyond the cap.
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes();
        let mut r = &huge[..];
        assert!(read_frame(&mut r).is_err());
        // Valid length, garbage payload.
        let mut bad: Vec<u8> = 4u32.to_be_bytes().to_vec();
        bad.extend_from_slice(b"zzzz");
        let mut r = &bad[..];
        assert!(read_frame(&mut r).is_err());
    }
}
