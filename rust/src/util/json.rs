//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar; numbers are f64 (adequate for the
//! manifest/weights metadata this repo reads).  Not performance-critical.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.path(&["design_point", "sigma_z"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json error at byte {at}: {msg}")]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON from our own tools never
                            // emits them; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run.
                    let start = self.i - 1;
                    while let Some(n) = self.peek() {
                        if n == b'"' || n == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience builders used by the figure/CSV writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\""}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["b", "d"]).unwrap().as_bool(), Some(true));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(j.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn number_forms() {
        for (s, v) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }
}
