//! Minimal JSON parser/writer (no serde in the offline vendor set).
//!
//! Supports the full JSON grammar; numbers are f64 (adequate for the
//! manifest/weights metadata this repo reads — u64 request ids travel as
//! decimal strings on the wire, see [`crate::serve::net::wire`]).  Also
//! home of the length-prefixed frame reader/writer the serving wire layer
//! streams JSON values over ([`read_frame`]/[`write_frame`]), and of
//! [`LazyObject`] — a single-pass field extractor the HTTP ingress uses
//! to pull `id`/`pixels`/`trials` out of a request body without
//! materializing the full tree.  The tree parser is not
//! performance-critical; the lazy scanner is on the ingress hot path.

use std::collections::BTreeMap;
use std::fmt;
use std::io::{self, Read, Write};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.path(&["design_point", "sigma_z"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: JSON from our own tools never
                            // emits them; map lone surrogates to U+FFFD.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the raw UTF-8 byte run.
                    let start = self.i - 1;
                    while let Some(n) = self.peek() {
                        if n == b'"' || n == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

// ---------------------------------------------------------------------------
// Length-prefixed JSON frames (the serve::net wire format)
// ---------------------------------------------------------------------------

/// Hard cap on a single frame's payload.  A length prefix beyond this is
/// treated as a corrupt (or hostile) stream instead of an allocation
/// request; a full 784-pixel request frame is ~20 KiB, so 16 MiB leaves
/// three orders of magnitude of headroom.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// Write `j` as one frame: a 4-byte big-endian payload length, then the
/// compact JSON bytes.  Flushes, so a frame is on the wire when this
/// returns.
pub fn write_frame<W: Write>(w: &mut W, j: &Json) -> io::Result<()> {
    let payload = j.to_string();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("refusing to write a {}-byte frame (cap {MAX_FRAME_BYTES})", bytes.len()),
        ));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(bytes)?;
    w.flush()
}

/// Read one frame off a byte stream.  `Ok(None)` on a clean EOF at a
/// frame boundary (the peer hung up between messages); EOF inside a
/// frame, an oversized length prefix, or a payload that is not valid
/// JSON all surface as `InvalidData` errors — the caller should drop the
/// connection, not retry.
pub fn read_frame<R: Read>(r: &mut R) -> io::Result<Option<Json>> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "stream ended inside a frame header",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let n = u32::from_be_bytes(len) as usize;
    if n > MAX_FRAME_BYTES {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length {n} exceeds the {MAX_FRAME_BYTES}-byte cap"),
        ));
    }
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            io::Error::new(io::ErrorKind::InvalidData, "stream ended inside a frame payload")
        } else {
            e
        }
    })?;
    let text = std::str::from_utf8(&buf)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "frame payload is not UTF-8"))?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("bad frame payload: {e}")))
}

/// Convenience builders used by the figure/CSV writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

// ---------------------------------------------------------------------------
// Lazy field extraction (the HTTP ingress hot path)
// ---------------------------------------------------------------------------

/// Single-pass field extractor over a top-level JSON object.
///
/// `Json::parse` builds a `BTreeMap`/`Vec` tree — fine for manifests,
/// wasteful for an ingress that only needs three fields out of a body
/// whose bulk is one large pixel array.  `LazyObject` instead scans the
/// raw bytes: values for keys the caller never asks about are *skipped*
/// (escape- and nesting-aware, no allocation), and the one array we do
/// want is decoded straight into a `Vec<f32>` without an intermediate
/// `Json::Arr` of boxed `f64`s.
///
/// Laziness has a deliberate blind spot: bytes *after* the requested
/// key's value are never inspected, so trailing garbage in an otherwise
/// well-formed prefix goes unnoticed.  Callers validate the fields they
/// use, which is exactly the admission-control posture the ingress wants
/// — spend parse effort proportional to what the request is worth.
pub struct LazyObject<'a> {
    b: &'a [u8],
}

impl<'a> LazyObject<'a> {
    /// Wrap a byte slice expected to hold a JSON object.  Nothing is
    /// scanned until a field accessor runs.
    pub fn new(b: &'a [u8]) -> Self {
        LazyObject { b }
    }

    /// Raw byte span of the value for top-level `key` (first
    /// occurrence), or `Ok(None)` if the key is absent.
    pub fn raw(&self, key: &str) -> Result<Option<&'a [u8]>, JsonError> {
        let mut s = Scan { b: self.b, i: 0 };
        s.skip_ws();
        s.eat(b'{')?;
        s.skip_ws();
        if s.peek() == Some(b'}') {
            return Ok(None);
        }
        loop {
            s.skip_ws();
            let (kb, escaped) = s.string_span()?;
            s.skip_ws();
            s.eat(b':')?;
            s.skip_ws();
            // Escaped keys can't byte-compare; our protocol keys are
            // plain ASCII, so an escaped key simply never matches.
            if !escaped && kb == key.as_bytes() {
                let start = s.i;
                s.skip_value()?;
                return Ok(Some(&s.b[start..s.i]));
            }
            s.skip_value()?;
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.i += 1,
                Some(b'}') => return Ok(None),
                _ => return Err(s.err("expected ',' or '}'")),
            }
        }
    }

    /// `u64` field that accepts both a bare integer and the wire
    /// layer's decimal-string form (`"id": "42"`), mirroring
    /// `serve::net::wire`'s id discipline.
    pub fn u64_field(&self, key: &str) -> Result<Option<u64>, JsonError> {
        let Some(raw) = self.raw(key)? else { return Ok(None) };
        let txt = if raw.len() >= 2 && raw[0] == b'"' && raw[raw.len() - 1] == b'"' {
            &raw[1..raw.len() - 1]
        } else {
            raw
        };
        std::str::from_utf8(txt)
            .ok()
            .and_then(|t| t.trim().parse::<u64>().ok())
            .map(Some)
            .ok_or_else(|| JsonError {
                at: 0,
                msg: format!("field '{key}' is not a non-negative integer"),
            })
    }

    /// Unescaped string field.
    pub fn str_field(&self, key: &str) -> Result<Option<String>, JsonError> {
        let Some(raw) = self.raw(key)? else { return Ok(None) };
        let mut p = Parser { b: raw, i: 0 };
        p.string()
            .map(Some)
            .map_err(|_| JsonError { at: 0, msg: format!("field '{key}' is not a string") })
    }

    /// Number array decoded straight into `Vec<f32>` — the pixel fast
    /// path.  Each element round-trips through `str::parse::<f32>`, so a
    /// client that prints `f32`s with Rust's shortest representation
    /// gets bit-identical values back (the parity tests rely on this).
    pub fn f32_array(&self, key: &str) -> Result<Option<Vec<f32>>, JsonError> {
        let Some(raw) = self.raw(key)? else { return Ok(None) };
        let mut s = Scan { b: raw, i: 0 };
        s.skip_ws();
        s.eat(b'[')?;
        let mut out = Vec::new();
        s.skip_ws();
        if s.peek() == Some(b']') {
            return Ok(Some(out));
        }
        loop {
            s.skip_ws();
            let start = s.i;
            if s.peek() == Some(b'-') {
                s.i += 1;
            }
            while let Some(c) = s.peek() {
                if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                    s.i += 1;
                } else {
                    break;
                }
            }
            let v = std::str::from_utf8(&s.b[start..s.i])
                .ok()
                .and_then(|t| t.parse::<f32>().ok())
                .ok_or_else(|| JsonError {
                    at: start,
                    msg: format!("field '{key}' has a non-numeric element"),
                })?;
            out.push(v);
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.i += 1,
                Some(b']') => return Ok(Some(out)),
                _ => return Err(s.err("expected ',' or ']'")),
            }
        }
    }
}

/// Byte cursor that *skips* values instead of building them — the
/// structural half of [`Parser`] without the allocation half.
struct Scan<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scan<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    /// Advance past a string literal; returns the span between the
    /// quotes (borrowing the underlying buffer, not the cursor) and
    /// whether it contained any escape.
    fn string_span(&mut self) -> Result<(&'a [u8], bool), JsonError> {
        self.eat(b'"')?;
        let start = self.i;
        let mut escaped = false;
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    let span = &self.b[start..self.i];
                    self.i += 1;
                    return Ok((span, escaped));
                }
                b'\\' => {
                    escaped = true;
                    self.i += 1;
                    if self.peek().is_none() {
                        return Err(self.err("bad escape"));
                    }
                    self.i += 1;
                }
                _ => self.i += 1,
            }
        }
    }

    /// Skip one complete JSON value, nesting-aware, without building it.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        let mut depth = 0usize;
        loop {
            self.skip_ws();
            match self.peek().ok_or_else(|| self.err("unexpected end of value"))? {
                b'{' | b'[' => {
                    depth += 1;
                    self.i += 1;
                }
                b'}' | b']' => {
                    if depth == 0 {
                        return Err(self.err("unexpected close bracket"));
                    }
                    depth -= 1;
                    self.i += 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                b'"' => {
                    self.string_span()?;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                b',' | b':' => {
                    if depth == 0 {
                        return Err(self.err("unexpected separator"));
                    }
                    self.i += 1;
                }
                _ => {
                    // Literal / number token; every structural byte is
                    // handled above, so this consumes at least one byte.
                    while let Some(c) = self.peek() {
                        if matches!(c, b',' | b':' | b'}' | b']' | b'{' | b'[' | b'"')
                            || c.is_ascii_whitespace()
                        {
                            break;
                        }
                        self.i += 1;
                    }
                    if depth == 0 {
                        return Ok(());
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\n\"y\""}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.path(&["b", "d"]).unwrap().as_bool(), Some(true));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn nested_arrays() {
        let j = Json::parse("[[1,2],[3,[4]]]").unwrap();
        assert_eq!(j.idx(1).unwrap().idx(1).unwrap().idx(0).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn number_forms() {
        for (s, v) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn frames_round_trip_back_to_back() {
        let a = obj(vec![("x", num(1.5)), ("s", Json::Str("hé\"llo".into()))]);
        let b = Json::Arr(vec![Json::Null, Json::Bool(true)]);
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(a));
        assert_eq!(read_frame(&mut r).unwrap(), Some(b));
        // Clean EOF at the frame boundary.
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn lazy_object_extracts_fields_without_the_tree() {
        let body = br#"{"meta": {"a": [1, {"b": "}]"}]}, "id": "42", "pixels": [0.5, -1.25, 3e2], "trials": 16, "tag": "x\"y"}"#;
        let doc = LazyObject::new(body);
        assert_eq!(doc.u64_field("id").unwrap(), Some(42));
        assert_eq!(doc.u64_field("trials").unwrap(), Some(16));
        assert_eq!(doc.f32_array("pixels").unwrap(), Some(vec![0.5, -1.25, 300.0]));
        assert_eq!(doc.str_field("tag").unwrap(), Some("x\"y".to_string()));
        assert_eq!(doc.u64_field("missing").unwrap(), None);
        assert_eq!(doc.raw("meta").unwrap(), Some(&br#"{"a": [1, {"b": "}]"}]}"#[..]));
    }

    #[test]
    fn lazy_object_agrees_with_the_full_parser() {
        let body = r#"{"id": 7, "pixels": [0, 0.1176470588235294, 1], "trials": 3}"#;
        let full = Json::parse(body).unwrap();
        let doc = LazyObject::new(body.as_bytes());
        assert_eq!(doc.u64_field("id").unwrap(), Some(full.get("id").unwrap().as_f64().unwrap() as u64));
        let lazy_px = doc.f32_array("pixels").unwrap().unwrap();
        let full_px: Vec<f32> =
            full.get("pixels").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
        assert_eq!(lazy_px, full_px);
    }

    #[test]
    fn lazy_object_f32_round_trips_shortest_repr() {
        // The parity tests depend on print → parse being the identity
        // for f32: verify over a spread of awkward values.
        for v in [0.0f32, 1.0, -0.25, 1.0 / 17.0, 13.0 / 17.0, f32::MIN_POSITIVE, 3.4e38] {
            let body = format!(r#"{{"pixels": [{v}]}}"#);
            let got = LazyObject::new(body.as_bytes()).f32_array("pixels").unwrap().unwrap();
            assert_eq!(got[0].to_bits(), v.to_bits(), "{v}");
        }
    }

    #[test]
    fn lazy_object_rejects_malformed_bodies() {
        for bad in [
            &b"not json"[..],
            b"[1,2,3]",
            b"{\"id\": }",
            b"{\"id\" 4}",
            b"{\"pixels\": [1,]}",
            b"{\"id\": \"x\"}",
            b"{\"pixels\": [\"a\"]}",
            b"{\"id\": 4",
        ] {
            let doc = LazyObject::new(bad);
            let id = doc.u64_field("id");
            let px = doc.f32_array("pixels");
            assert!(id.is_err() || px.is_err(), "{:?}", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn truncated_and_oversized_frames_error() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &num(7.0)).unwrap();
        // EOF inside the header.
        let mut r = &buf[..2];
        assert!(read_frame(&mut r).is_err());
        // EOF inside the payload.
        let mut r = &buf[..buf.len() - 1];
        assert!(read_frame(&mut r).is_err());
        // Length prefix beyond the cap.
        let huge = ((MAX_FRAME_BYTES + 1) as u32).to_be_bytes();
        let mut r = &huge[..];
        assert!(read_frame(&mut r).is_err());
        // Valid length, garbage payload.
        let mut bad: Vec<u8> = 4u32.to_be_bytes().to_vec();
        bad.extend_from_slice(b"zzzz");
        let mut r = &bad[..];
        assert!(read_frame(&mut r).is_err());
    }
}
