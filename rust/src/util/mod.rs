//! Small self-contained utilities (the offline build has no serde/rand/clap).

pub mod bench;
pub mod json;
pub mod logging;
pub mod simd;
pub mod table;
