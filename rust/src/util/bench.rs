//! Minimal benchmark harness (no criterion in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations, mean ± std, and throughput reporting.  Results are
//! also appended to `results/bench.csv` for the §Perf log.

use std::time::{Duration, Instant};

use crate::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
    /// Optional work units per iteration (for ops/s reporting).
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn units_per_sec(&self) -> f64 {
        self.units_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:44} {:>10.3?} ±{:>9.3?} (min {:>9.3?}, {} iters",
            self.name, self.mean, self.std, self.min, self.iters
        )?;
        if self.units_per_iter > 0.0 {
            write!(f, ", {:.1} units/s", self.units_per_sec())?;
        }
        write!(f, ")")
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench_units<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    mut f: F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    let mut min = Duration::MAX;
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed();
        s.add(dt.as_secs_f64());
        min = min.min(dt);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(s.mean()),
        std: Duration::from_secs_f64(if s.count() > 1 { s.std() } else { 0.0 }),
        min,
        units_per_iter,
    };
    println!("{r}");
    append_csv(&r);
    r
}

/// Time `f` (no unit accounting).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    bench_units(name, warmup, iters, 0.0, f)
}

fn append_csv(r: &BenchResult) {
    let dir = std::path::PathBuf::from(
        std::env::var("RACA_RESULTS").unwrap_or_else(|_| "results".into()),
    );
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join("bench.csv");
    let new = !path.exists();
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        use std::io::Write;
        if new {
            let _ = writeln!(f, "name,iters,mean_s,std_s,min_s,units_per_iter");
        }
        let _ = writeln!(
            f,
            "{},{},{:.9},{:.9},{:.9},{}",
            r.name,
            r.iters,
            r.mean.as_secs_f64(),
            r.std.as_secs_f64(),
            r.min.as_secs_f64(),
            r.units_per_iter
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench_units("spin", 1, 5, 1000.0, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.mean > Duration::ZERO);
        assert!(r.units_per_sec() > 0.0);
    }
}
