//! Tiny stderr logger backend for the `log` facade (no env_logger offline).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger {
    level: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!("[{:5}] {}: {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; level from `RACA_LOG` (error..trace, default info).
pub fn init() {
    let level = match std::env::var("RACA_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { level }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(LevelFilter::Trace);
    }
}
