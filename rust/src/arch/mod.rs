//! RACA chip architecture (DESIGN.md §4: floorplan + pipeline model).
//!
//! Maps the logical FCNN onto the physical chip: which crossbar tiles
//! implement which layer slice, how layers pipeline across consecutive
//! inputs, and the resulting utilization / throughput — the piece that
//! turns the per-component cost model into a *system* (paper §III-C:
//! "the number of neural network layers and specifications supported by
//! this architecture can be flexibly configured").

pub mod floorplan;
pub mod pipeline;
pub mod shard;

pub use floorplan::{Floorplan, TileAssignment};
pub use pipeline::{PipelineModel, PipelineReport};
pub use shard::ShardPlan;
