//! Layer-pipeline timing model.
//!
//! RACA layers are physically distinct crossbars, so consecutive *inputs*
//! pipeline: while the output layer runs its WTA race on image k, the
//! hidden layers already process image k+1.  Throughput is set by the
//! slowest stage; per-image latency by the sum.  This model feeds the
//! throughput side of Table I and exposes the WTA race as the pipeline
//! bottleneck the paper's V_th0 discussion implies ("high V_th0 …
//! prolongs a single decision time").

use crate::hwmodel::{Architecture, TechParams};
use crate::nn::ModelSpec;

use super::floorplan::Floorplan;

/// Per-stage and aggregate timing.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    /// Per-layer stage latency [ns] (one trial through that layer).
    pub stage_ns: Vec<f64>,
    /// Per-image latency (sum of stages) [ns].
    pub latency_ns: f64,
    /// Pipeline initiation interval = slowest stage [ns].
    pub ii_ns: f64,
    /// Trials per second at full pipeline occupancy.
    pub trials_per_sec: f64,
    /// Index of the bottleneck stage.
    pub bottleneck: usize,
}

/// Timing model over a placed network.
#[derive(Debug, Clone)]
pub struct PipelineModel {
    pub floorplan: Floorplan,
    pub tech: TechParams,
    pub arch: Architecture,
    /// Expected WTA steps per decision (depends on V_th0; the paper's
    /// 0.05 V point decides in a handful of steps, worst case wta_steps).
    pub expected_wta_steps: f64,
}

impl PipelineModel {
    pub fn new(spec: ModelSpec, tech: TechParams, arch: Architecture) -> Self {
        let tile = tech.tile;
        Self {
            floorplan: Floorplan::place(spec, tile, 8),
            expected_wta_steps: tech.wta_steps as f64 / 8.0,
            tech,
            arch,
        }
    }

    pub fn paper_raca() -> Self {
        Self::new(ModelSpec::paper(), TechParams::default(), Architecture::Raca)
    }

    /// Expected decision steps from the threshold depth: the per-step
    /// any-neuron crossing probability p gives a geometric wait 1/p.
    pub fn set_wta_expectation_from_theta(&mut self, theta_norm: f64, classes: usize) {
        // p_step ≈ 1 − (1 − Φ(−θ/1.702))^C for near-tied neurons.
        let p1 = crate::stats::erf::norm_cdf(-theta_norm / 1.702);
        let p_step = 1.0 - (1.0 - p1).powi(classes as i32);
        self.expected_wta_steps =
            (1.0 / p_step.max(1e-9)).min(self.tech.wta_steps as f64);
    }

    pub fn report(&self) -> PipelineReport {
        let t = &self.tech;
        let spec = &self.floorplan.spec;
        let n_layers = spec.num_layers();
        let mut stage_ns = Vec::with_capacity(n_layers);
        for l in 0..n_layers {
            let last = l == n_layers - 1;
            let cycles = if l == 0 {
                t.input_cycles as f64
            } else if last && self.arch == Architecture::Raca {
                self.expected_wta_steps
            } else {
                1.0
            };
            let per_cycle = match self.arch {
                Architecture::OneBitAdc => 2.0 * t.t_read * 1e9,
                Architecture::Raca => t.t_read * 1e9,
            };
            stage_ns.push(cycles * per_cycle);
        }
        let latency_ns: f64 = stage_ns.iter().sum();
        let (bottleneck, &ii_ns) = stage_ns
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        PipelineReport {
            trials_per_sec: 1e9 / ii_ns,
            stage_ns,
            latency_ns,
            ii_ns,
            bottleneck,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_beats_serial_latency() {
        let m = PipelineModel::paper_raca();
        let r = m.report();
        assert!(r.ii_ns <= r.latency_ns);
        assert!(r.trials_per_sec > 0.0);
        assert_eq!(r.stage_ns.len(), 3);
    }

    #[test]
    fn input_layer_is_bottleneck_at_low_theta() {
        // With a shallow threshold the WTA decides in ~1 step, so the
        // 8-cycle bit-serial input layer dominates.
        let mut m = PipelineModel::paper_raca();
        m.set_wta_expectation_from_theta(0.0, 10);
        let r = m.report();
        assert_eq!(r.bottleneck, 0, "stages {:?}", r.stage_ns);
    }

    #[test]
    fn deep_threshold_slows_decisions() {
        let mut shallow = PipelineModel::paper_raca();
        shallow.set_wta_expectation_from_theta(1.0, 10);
        let mut deep = PipelineModel::paper_raca();
        deep.set_wta_expectation_from_theta(6.0, 10);
        assert!(
            deep.expected_wta_steps > 4.0 * shallow.expected_wta_steps,
            "deep {} vs shallow {}",
            deep.expected_wta_steps,
            shallow.expected_wta_steps
        );
        assert!(deep.report().latency_ns > shallow.report().latency_ns);
    }

    #[test]
    fn wta_expectation_capped_at_horizon() {
        let mut m = PipelineModel::paper_raca();
        m.set_wta_expectation_from_theta(50.0, 10);
        assert!(m.expected_wta_steps <= m.tech.wta_steps as f64);
    }

    #[test]
    fn adc_baseline_pays_conversion_cycle() {
        let raca = PipelineModel::paper_raca().report();
        let adc = PipelineModel::new(
            ModelSpec::paper(),
            TechParams::default(),
            Architecture::OneBitAdc,
        )
        .report();
        // Hidden-layer stages: RACA 1 ns vs ADC 2 ns.
        assert!(adc.stage_ns[1] > raca.stage_ns[1]);
    }
}
