//! Shard plan: split one model's layers across multiple dies.
//!
//! The paper's architecture supports a configurable number of layers per
//! chip (§III-C); when a model outgrows one die — or when throughput
//! demands a deeper hardware pipeline — consecutive layers are placed on
//! *different* chips and activations stream die-to-die (the tiled /
//! pipelined multi-chip organizations surveyed in Smagulova et al.,
//! arXiv:2109.03934).  The layer is the atomic stage: its crossbars must
//! share a die because a column's currents sum in analog.
//!
//! [`ShardPlan::balanced`] partitions the layer sequence into contiguous
//! ranges, one per die, minimizing the worst die's crossbar-tile demand
//! as computed by the [`Floorplan`] — tile count is the die's area/
//! capacity budget, the quantity a real multi-die deployment must bound.
//! [`crate::serve::PipelinedFleetBackend`] executes this plan; every
//! `pipeline:<dies>` leaf of a [`crate::serve::Topology`] tree gets its
//! own instance (replicated pipelines re-plan identically but program
//! distinct silicon — the topology compiler numbers their dies apart).

use crate::nn::ModelSpec;

use super::floorplan::Floorplan;

/// Contiguous layer-range-per-die assignment.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub spec: ModelSpec,
    /// Crossbar tile edge used for the balance criterion.
    pub tile: usize,
    /// Global-layer range each die owns, in pipeline order.
    pub ranges: Vec<std::ops::Range<usize>>,
    /// Tiles each die must provision (sum over its layers' floorplans).
    pub tiles_per_die: Vec<usize>,
}

impl ShardPlan {
    /// Optimal contiguous partition of `spec`'s layers across `dies`
    /// chips, minimizing the maximum per-die tile demand.
    ///
    /// Errors (instead of a downstream panic) when `dies == 0` or when
    /// `dies` exceeds the layer count — a layer cannot straddle dies.
    pub fn balanced(spec: &ModelSpec, tile: usize, dies: usize) -> Result<Self, String> {
        let n = spec.num_layers();
        if dies == 0 {
            return Err("shard plan needs at least one die".into());
        }
        if dies > n {
            return Err(format!(
                "cannot shard a {n}-layer model across {dies} dies: a layer is the \
                 atomic pipeline stage, so at most {n} dies are usable"
            ));
        }
        // Per-layer tile demand from the single-chip floorplan.
        let fp = Floorplan::place(spec.clone(), tile, 8);
        let layer_tiles: Vec<usize> = (0..n).map(|l| fp.layer_tiles(l).len()).collect();
        // Prefix sums: weight of layers [a, b) = pre[b] - pre[a].
        let mut pre = vec![0usize; n + 1];
        for (l, &t) in layer_tiles.iter().enumerate() {
            pre[l + 1] = pre[l] + t;
        }
        let seg = |a: usize, b: usize| pre[b] - pre[a];

        // DP over contiguous partitions: best[k][i] = minimal possible
        // maximum die weight when the first i layers occupy k dies.
        let inf = usize::MAX;
        let mut best = vec![vec![inf; n + 1]; dies + 1];
        let mut cut = vec![vec![0usize; n + 1]; dies + 1];
        best[0][0] = 0;
        for k in 1..=dies {
            for i in k..=n {
                for j in (k - 1)..i {
                    if best[k - 1][j] == inf {
                        continue;
                    }
                    let cand = best[k - 1][j].max(seg(j, i));
                    if cand < best[k][i] {
                        best[k][i] = cand;
                        cut[k][i] = j;
                    }
                }
            }
        }
        // Reconstruct the cut points back-to-front.
        let mut bounds = vec![n];
        let mut i = n;
        for k in (1..=dies).rev() {
            i = cut[k][i];
            bounds.push(i);
        }
        bounds.reverse();
        debug_assert_eq!(bounds[0], 0);
        let ranges: Vec<std::ops::Range<usize>> =
            bounds.windows(2).map(|w| w[0]..w[1]).collect();
        let tiles_per_die = ranges.iter().map(|r| seg(r.start, r.end)).collect();
        Ok(Self { spec: spec.clone(), tile, ranges, tiles_per_die })
    }

    pub fn dies(&self) -> usize {
        self.ranges.len()
    }

    /// The worst die's tile demand (the balance objective).
    pub fn max_tiles(&self) -> usize {
        self.tiles_per_die.iter().copied().max().unwrap_or(0)
    }

    /// Sub-network topology of one die: `widths[start..=end]` of the
    /// global spec (a die's last layer's outputs are the next die's
    /// inputs).
    pub fn sub_spec(&self, die: usize) -> ModelSpec {
        let r = &self.ranges[die];
        ModelSpec::new(self.spec.widths[r.start..=r.end].to_vec())
    }

    /// Gaussian draws consumed per trial by all dies *upstream* of `die`:
    /// one comparator-noise draw per binarized hidden neuron, i.e.
    /// `widths[l+1]` for every global layer `l` before this die's range.
    /// The die skips that many draws off the shared per-trial stream so
    /// sharded execution consumes bit-identical noise to the unsharded
    /// engine.
    pub fn noise_skip(&self, die: usize) -> usize {
        (0..self.ranges[die].start)
            .map(|l| self.spec.widths[l + 1])
            .sum()
    }

    /// Sanity: ranges are non-empty, contiguous, and cover every layer.
    pub fn validate(&self) -> Result<(), String> {
        let mut next = 0usize;
        for (d, r) in self.ranges.iter().enumerate() {
            if r.start != next || r.is_empty() {
                return Err(format!("die {d} owns {r:?}, expected to start at {next}"));
            }
            next = r.end;
        }
        if next != self.spec.num_layers() {
            return Err(format!(
                "plan covers {next} layers, model has {}",
                self.spec.num_layers()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_zero_and_oversharded() {
        let spec = ModelSpec::paper(); // 3 layers
        assert!(ShardPlan::balanced(&spec, 128, 0).is_err());
        assert!(ShardPlan::balanced(&spec, 128, 4).is_err());
        assert!(ShardPlan::balanced(&spec, 128, 3).is_ok());
    }

    #[test]
    fn paper_model_across_two_dies_balances_tiles() {
        // Paper layers need 28 / 12 / 3 tiles; the optimal contiguous
        // 2-split is [28] | [12, 3] with max 28.
        let plan = ShardPlan::balanced(&ModelSpec::paper(), 128, 2).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.ranges, vec![0..1, 1..3]);
        assert_eq!(plan.tiles_per_die, vec![28, 15]);
        assert_eq!(plan.max_tiles(), 28);
    }

    #[test]
    fn one_die_per_layer_when_fully_sharded() {
        let plan = ShardPlan::balanced(&ModelSpec::paper(), 128, 3).unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.ranges, vec![0..1, 1..2, 2..3]);
        assert_eq!(plan.tiles_per_die, vec![28, 12, 3]);
    }

    #[test]
    fn sub_specs_chain_input_to_output() {
        let spec = ModelSpec::new(vec![784, 256, 128, 64, 10]);
        let plan = ShardPlan::balanced(&spec, 128, 3).unwrap();
        plan.validate().unwrap();
        // Consecutive dies agree on the activation width at the seam, and
        // the chain preserves the end-to-end dimensions.
        for d in 0..plan.dies() - 1 {
            assert_eq!(
                plan.sub_spec(d).output_dim(),
                plan.sub_spec(d + 1).input_dim(),
                "die {d} → {} seam width mismatch",
                d + 1
            );
        }
        assert_eq!(plan.sub_spec(0).input_dim(), 784);
        assert_eq!(plan.sub_spec(plan.dies() - 1).output_dim(), 10);
    }

    #[test]
    fn noise_skip_counts_upstream_hidden_neurons() {
        let spec = ModelSpec::new(vec![784, 256, 128, 64, 10]);
        let plan = ShardPlan::balanced(&spec, 128, 4).unwrap();
        // Fully sharded: die d skips every upstream layer's fan-out.
        assert_eq!(plan.noise_skip(0), 0);
        assert_eq!(plan.noise_skip(1), 256);
        assert_eq!(plan.noise_skip(2), 256 + 128);
        assert_eq!(plan.noise_skip(3), 256 + 128 + 64);
    }

    #[test]
    fn balance_is_optimal_for_a_known_split() {
        // Weights 14/6/2/2 (the bench model at tile 128): the optimal
        // 2-split is [14] | [6, 2, 2] (max 14), not [14, 6] | [2, 2].
        let spec = ModelSpec::new(vec![784, 256, 192, 128, 10]);
        let plan = ShardPlan::balanced(&spec, 128, 2).unwrap();
        assert_eq!(plan.ranges, vec![0..1, 1..4]);
        assert_eq!(plan.max_tiles(), 14);
    }
}
