//! Floorplan: assign logical layer slices to physical crossbar tiles.
//!
//! The chip is a grid of identical 128×128 crossbar tiles (plus their
//! column periphery).  Each layer needs `ceil(rows/T)·ceil(cols/T)`
//! tiles; the floorplanner packs layers onto the grid row-major, records
//! the assignment, and reports utilization — both device-level (cells
//! actually programmed vs provisioned) and tile-level.

use crate::nn::ModelSpec;

/// One tile's assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileAssignment {
    /// Physical tile index (row-major on the chip grid).
    pub tile: usize,
    /// Owning layer.
    pub layer: usize,
    /// Row/col block within the layer's logical matrix.
    pub block_row: usize,
    pub block_col: usize,
    /// Occupied extent (edge tiles are partially filled).
    pub used_rows: usize,
    pub used_cols: usize,
}

/// A placed network.
#[derive(Debug, Clone)]
pub struct Floorplan {
    pub spec: ModelSpec,
    pub tile: usize,
    pub assignments: Vec<TileAssignment>,
    /// Chip grid width in tiles (for x/y coordinates).
    pub grid_width: usize,
}

impl Floorplan {
    /// Pack `spec` onto a chip with `grid_width` tiles per row.
    pub fn place(spec: ModelSpec, tile: usize, grid_width: usize) -> Self {
        assert!(tile > 0 && grid_width > 0);
        let mut assignments = Vec::new();
        let mut next = 0usize;
        for l in 0..spec.num_layers() {
            let (rows, cols) = spec.layer_shape(l);
            let brs = rows.div_ceil(tile);
            let bcs = cols.div_ceil(tile);
            for br in 0..brs {
                for bc in 0..bcs {
                    assignments.push(TileAssignment {
                        tile: next,
                        layer: l,
                        block_row: br,
                        block_col: bc,
                        used_rows: tile.min(rows - br * tile),
                        used_cols: tile.min(cols - bc * tile),
                    });
                    next += 1;
                }
            }
        }
        Self { spec, tile, assignments, grid_width }
    }

    pub fn num_tiles(&self) -> usize {
        self.assignments.len()
    }

    /// Physical (x, y) tile coordinates.
    pub fn tile_xy(&self, tile: usize) -> (usize, usize) {
        (tile % self.grid_width, tile / self.grid_width)
    }

    /// Device utilization: programmed cells / provisioned cells.
    pub fn device_utilization(&self) -> f64 {
        let used: usize = self
            .assignments
            .iter()
            .map(|a| a.used_rows * a.used_cols)
            .sum();
        used as f64 / (self.num_tiles() * self.tile * self.tile) as f64
    }

    /// Tiles of one layer.
    pub fn layer_tiles(&self, layer: usize) -> Vec<&TileAssignment> {
        self.assignments.iter().filter(|a| a.layer == layer).collect()
    }

    /// Manhattan distance (in tile pitches) between the centroids of two
    /// consecutive layers — the activation-routing distance the H-tree
    /// model charges.
    pub fn layer_hop_distance(&self, from_layer: usize) -> f64 {
        let centroid = |l: usize| -> (f64, f64) {
            let tiles = self.layer_tiles(l);
            let n = tiles.len() as f64;
            let (sx, sy) = tiles.iter().fold((0.0, 0.0), |(sx, sy), a| {
                let (x, y) = self.tile_xy(a.tile);
                (sx + x as f64, sy + y as f64)
            });
            (sx / n, sy / n)
        };
        let (x0, y0) = centroid(from_layer);
        let (x1, y1) = centroid(from_layer + 1);
        (x1 - x0).abs() + (y1 - y0).abs()
    }

    /// Sanity: every logical cell covered exactly once.
    pub fn validate(&self) -> Result<(), String> {
        for l in 0..self.spec.num_layers() {
            let (rows, cols) = self.spec.layer_shape(l);
            let covered: usize = self
                .layer_tiles(l)
                .iter()
                .map(|a| a.used_rows * a.used_cols)
                .sum();
            if covered != rows * cols {
                return Err(format!(
                    "layer {l}: covered {covered} cells, expected {}",
                    rows * cols
                ));
            }
        }
        // No tile double-booked.
        let mut seen = std::collections::HashSet::new();
        for a in &self.assignments {
            if !seen.insert(a.tile) {
                return Err(format!("tile {} double-booked", a.tile));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_plan() -> Floorplan {
        Floorplan::place(ModelSpec::paper(), 128, 8)
    }

    #[test]
    fn paper_network_tile_count() {
        let fp = paper_plan();
        assert_eq!(fp.num_tiles(), 28 + 12 + 3);
        fp.validate().unwrap();
    }

    #[test]
    fn utilization_accounts_for_edge_tiles() {
        let fp = paper_plan();
        let u = fp.device_utilization();
        // 785·500 + 501·300 + 301·10 programmed out of 43·128² provisioned.
        let want = (785.0 * 500.0 + 501.0 * 300.0 + 301.0 * 10.0) / (43.0 * 128.0 * 128.0);
        assert!((u - want).abs() < 1e-12, "{u} vs {want}");
        assert!(u > 0.5 && u < 1.0);
    }

    #[test]
    fn exact_fit_is_full_utilization() {
        let fp = Floorplan::place(ModelSpec::new(vec![127, 128]), 128, 4);
        // layer shape (128, 128) → exactly one full tile.
        assert_eq!(fp.num_tiles(), 1);
        assert_eq!(fp.device_utilization(), 1.0);
    }

    #[test]
    fn hop_distances_are_finite_and_ordered() {
        let fp = paper_plan();
        for l in 0..fp.spec.num_layers() - 1 {
            let d = fp.layer_hop_distance(l);
            assert!(d.is_finite() && d >= 0.0);
        }
    }

    #[test]
    fn validate_catches_double_booking() {
        let mut fp = paper_plan();
        fp.assignments[1].tile = fp.assignments[0].tile;
        assert!(fp.validate().is_err());
    }

    #[test]
    fn xy_roundtrip() {
        let fp = paper_plan();
        assert_eq!(fp.tile_xy(0), (0, 0));
        assert_eq!(fp.tile_xy(8), (0, 1));
        assert_eq!(fp.tile_xy(11), (3, 1));
    }
}
