//! WTA binary stochastic SoftMax neuron layer (paper §III-B, Eq. 14).
//!
//! Wraps the transient WTA circuit with the counting/normalization logic:
//! repeated decision trials accumulate per-class win counts whose
//! normalized frequencies approximate softmax(Z) in the threshold-tail
//! regime; argmax of the cumulative counts is the classification result.

use crate::circuit::{WtaCircuit, WtaParams};
use crate::stats::{erf::norm_cdf, GaussianSource};

/// Outcome of a batch of WTA decision trials on one input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WtaOutcome {
    /// Win counts per class.
    pub counts: Vec<u64>,
    /// Trials that timed out (no neuron crossed within the horizon).
    pub abstentions: u64,
    /// Trials run.
    pub trials: u64,
}

impl WtaOutcome {
    pub fn new(classes: usize) -> Self {
        Self { counts: vec![0; classes], abstentions: 0, trials: 0 }
    }

    pub fn record(&mut self, winner: i32) {
        self.trials += 1;
        if winner < 0 {
            self.abstentions += 1;
        } else {
            self.counts[winner as usize] += 1;
        }
    }

    pub fn merge(&mut self, other: &WtaOutcome) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.abstentions += other.abstentions;
        self.trials += other.trials;
    }

    /// Predicted class: argmax of counts (ties → lower index; −1 if no
    /// trial produced a winner).
    pub fn prediction(&self) -> i32 {
        let best = self.counts.iter().enumerate().max_by(|a, b| {
            a.1.cmp(b.1).then(std::cmp::Ordering::Greater) // keep first max
        });
        match best {
            Some((i, &c)) if c > 0 => i as i32,
            _ => -1,
        }
    }

    /// Empirical win distribution (excluding abstentions).
    pub fn frequencies(&self) -> Vec<f64> {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts.iter().map(|&c| c as f64 / total as f64).collect()
    }

    /// Top-two vote counts (for the early-stopping rule).
    pub fn top_two(&self) -> (u64, u64) {
        let mut first = 0u64;
        let mut second = 0u64;
        for &c in &self.counts {
            if c > first {
                second = first;
                first = c;
            } else if c > second {
                second = c;
            }
        }
        (first, second)
    }
}

/// The output layer: static voltages → repeated WTA decisions.
#[derive(Debug, Clone)]
pub struct WtaLayer {
    pub circuit: WtaCircuit,
}

impl WtaLayer {
    pub fn new(params: WtaParams) -> Self {
        Self { circuit: WtaCircuit::new(params) }
    }

    /// Run `trials` decisions on static voltages `v` [V].
    pub fn run(&self, v: &[f64], trials: usize, gauss: &mut GaussianSource) -> WtaOutcome {
        let mut out = WtaOutcome::new(v.len());
        for _ in 0..trials {
            out.record(self.circuit.decide(v, gauss));
        }
        out
    }

    /// Analytic per-step crossing probability of each neuron:
    /// p_j = Φ((V_j − V_th)/σ_v) — the tail whose ratios softmax builds on.
    pub fn crossing_probabilities(&self, v: &[f64]) -> Vec<f64> {
        let vth = self.circuit.rest_threshold(v);
        let s = self.circuit.params.sigma_v;
        v.iter().map(|&vj| norm_cdf((vj - vth) / s)).collect()
    }

    /// Analytic WTA win distribution (Eq. 14): P_j / Σ_k P_k, ignoring the
    /// (second-order) simultaneous-crossing tie-breaks.
    pub fn analytic_win_distribution(&self, v: &[f64]) -> Vec<f64> {
        let p = self.crossing_probabilities(v);
        let total: f64 = p.iter().sum();
        if total <= 0.0 {
            return vec![0.0; v.len()];
        }
        p.iter().map(|&x| x / total).collect()
    }
}

/// Softmax over f64 logits (reference for Eq. 14 comparisons).
pub fn softmax64(z: &[f64]) -> Vec<f64> {
    let m = z.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let e: Vec<f64> = z.iter().map(|&v| (v - m).exp()).collect();
    let s: f64 = e.iter().sum();
    e.iter().map(|&v| v / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(sigma_v: f64, vth0: f64) -> WtaLayer {
        WtaLayer::new(WtaParams { sigma_v, vth0, ..Default::default() })
    }

    #[test]
    fn outcome_bookkeeping() {
        let mut o = WtaOutcome::new(3);
        for w in [0, 1, 1, -1, 2, 1] {
            o.record(w);
        }
        assert_eq!(o.counts, vec![1, 3, 1]);
        assert_eq!(o.abstentions, 1);
        assert_eq!(o.trials, 6);
        assert_eq!(o.prediction(), 1);
        assert_eq!(o.top_two(), (3, 1));
    }

    #[test]
    fn prediction_tie_breaks_low() {
        let mut o = WtaOutcome::new(3);
        o.record(2);
        o.record(1);
        assert_eq!(o.prediction(), 1);
    }

    #[test]
    fn empty_prediction_is_abstain() {
        let o = WtaOutcome::new(3);
        assert_eq!(o.prediction(), -1);
    }

    #[test]
    fn merge_adds() {
        let mut a = WtaOutcome::new(2);
        a.record(0);
        let mut b = WtaOutcome::new(2);
        b.record(1);
        b.record(-1);
        a.merge(&b);
        assert_eq!(a.counts, vec![1, 1]);
        assert_eq!(a.trials, 3);
        assert_eq!(a.abstentions, 1);
    }

    #[test]
    fn win_frequencies_approximate_softmax() {
        // Eq. 14: the win distribution ≈ softmax of the normalized logits
        // when the threshold sits at the softmax-matching depth.
        //
        // Mapping (DESIGN.md §6): v_j = σ_v·z_j/1.702 (κ = 1/1.702), and
        // d log P/dz = (θ_z − z̄)/1.702², so slope 1 needs the rest
        // threshold ≈ 1.702²·σ_v/1.702 = 1.702·σ_v above the mean logit.
        let sigma_v = 0.02;
        let z = [0.0f64, 0.6, 1.2];
        let z_mean = 0.6;
        let theta_z = z_mean + 1.702f64 * 1.702;
        let v: Vec<f64> = z.iter().map(|&zi| zi * sigma_v / 1.702).collect();
        let v_mean = v.iter().sum::<f64>() / v.len() as f64;
        let vth0 = (theta_z - z_mean) * sigma_v / 1.702
            - (v_mean - z_mean * sigma_v / 1.702); // rest = mean + vth0
        let l = layer(sigma_v, vth0);
        let mut g = GaussianSource::new(1);
        let o = l.run(&v, 30_000, &mut g);
        let f = o.frequencies();
        let want = softmax64(&z.to_vec());
        for (a, b) in f.iter().zip(&want) {
            assert!((a - b).abs() < 0.06, "{f:?} vs {want:?}");
        }
        // Ranking must match exactly.
        assert_eq!(o.prediction(), 2);
        // And the analytic Eq. 14 distribution should agree even closer.
        let analytic = l.analytic_win_distribution(&v);
        for (a, b) in analytic.iter().zip(&want) {
            assert!((a - b).abs() < 0.05, "{analytic:?} vs {want:?}");
        }
    }

    #[test]
    fn analytic_distribution_normalizes() {
        let l = layer(0.02, 0.06);
        let v = [0.0, 0.01, 0.02, 0.05];
        let d = l.analytic_win_distribution(&v);
        assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(d[3] > d[0]);
    }

    #[test]
    fn softmax64_matches_manual() {
        let p = softmax64(&[0.0, (2.0f64).ln()]);
        assert!((p[1] / p[0] - 2.0).abs() < 1e-12);
    }
}
