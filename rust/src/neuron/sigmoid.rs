//! Binary stochastic Sigmoid neuron (paper §III-A, Eq. 8–13).
//!
//! The neuron is *just* a comparator on a noisy differential column
//! current; its activation probability is Φ(κ·Z) ≈ sigmoid(Z).  This
//! module wraps that decision plus the analytic forms used by Fig. 4.

use crate::circuit::{Comparator, Tia};
use crate::stats::{erf::norm_cdf, GaussianSource};

/// One column's readout chain: TIA pair → subtractor → comparator.
#[derive(Debug, Clone)]
pub struct SigmoidNeuron {
    pub tia: Tia,
    pub comparator: Comparator,
}

impl SigmoidNeuron {
    /// Ideal chain with feedback resistance `r` (offsets/hysteresis zero).
    pub fn ideal(r: f64) -> Self {
        Self { tia: Tia::new(r), comparator: Comparator::ideal() }
    }

    /// One decision from a (noisy) differential current sample [A].
    #[inline]
    pub fn fire(&mut self, i_diff: f64, gauss: &mut GaussianSource) -> bool {
        let v = self.tia.transfer(i_diff);
        self.comparator.decide(v, 0.0, gauss)
    }

    /// Analytic activation probability given the mean differential current
    /// and the total column-noise RMS (Eq. 13): P = Φ(μ/σ).
    pub fn activation_probability(i_mean: f64, sigma_i: f64) -> f64 {
        if sigma_i <= 0.0 {
            return if i_mean > 0.0 { 1.0 } else { 0.0 };
        }
        norm_cdf(i_mean / sigma_i)
    }

    /// Normalized-unit form: P = Φ(κ·z) with κ = Vr·G0/σ_tot.
    pub fn activation_probability_z(z: f64, kappa: f64) -> f64 {
        norm_cdf(kappa * z)
    }

    /// Empirical activation frequency from `n` fresh noise samples of a
    /// fixed mean current (Fig. 4(a,b) sampling experiments).
    pub fn sample_probability(
        &mut self,
        i_mean: f64,
        sigma_i: f64,
        n: usize,
        gauss: &mut GaussianSource,
    ) -> f64 {
        let mut fired = 0usize;
        for _ in 0..n {
            let i = i_mean + sigma_i * gauss.next();
            if self.fire(i, gauss) {
                fired += 1;
            }
        }
        fired as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::SIGMOID_PROBIT;
    use crate::stats::erf::logistic;

    #[test]
    fn analytic_probability_limits() {
        assert!((SigmoidNeuron::activation_probability(0.0, 1e-9) - 0.5).abs() < 2e-7);
        assert!(SigmoidNeuron::activation_probability(1e-6, 1e-9) > 0.999);
        assert!(SigmoidNeuron::activation_probability(-1e-6, 1e-9) < 0.001);
        assert_eq!(SigmoidNeuron::activation_probability(1.0, 0.0), 1.0);
        assert_eq!(SigmoidNeuron::activation_probability(-1.0, 0.0), 0.0);
    }

    #[test]
    fn empirical_matches_analytic() {
        let mut n = SigmoidNeuron::ideal(1e5);
        let mut g = GaussianSource::new(1);
        for (mu, sigma) in [(0.0, 1e-6), (5e-7, 1e-6), (-1.2e-6, 1e-6)] {
            let p_hat = n.sample_probability(mu, sigma, 40_000, &mut g);
            let p = SigmoidNeuron::activation_probability(mu, sigma);
            assert!((p_hat - p).abs() < 0.01, "mu={mu}: {p_hat} vs {p}");
        }
    }

    #[test]
    fn calibrated_kappa_tracks_logistic() {
        let kappa = 1.0 / SIGMOID_PROBIT;
        for z in [-4.0, -1.0, 0.0, 0.5, 2.0, 4.0] {
            let p = SigmoidNeuron::activation_probability_z(z, kappa);
            assert!((p - logistic(z)).abs() < 0.0095, "z={z}");
        }
    }

    #[test]
    fn tia_saturation_degrades_extremes_only() {
        // A saturated TIA clips large |I| but the comparator decision for
        // clipped values is already deterministic — probability unchanged.
        let mut n = SigmoidNeuron::ideal(1e6);
        n.tia = n.tia.with_rail(0.1);
        let mut g = GaussianSource::new(2);
        let p = n.sample_probability(5e-7, 1e-6, 20_000, &mut g);
        let want = SigmoidNeuron::activation_probability(5e-7, 1e-6);
        assert!((p - want).abs() < 0.02);
    }

    #[test]
    fn paper_example_probabilities() {
        // Fig. 4(a,b): activation probabilities 0.014 and 0.745 correspond
        // to z = logit(p) at the calibrated point; check Φ(κ·z) lands close.
        let kappa = 1.0 / SIGMOID_PROBIT;
        for p_target in [0.014, 0.745] {
            let z = (p_target / (1.0 - p_target) as f64).ln();
            let p = SigmoidNeuron::activation_probability_z(z, kappa);
            assert!((p - p_target).abs() < 0.01, "target={p_target} got={p}");
        }
    }
}
