//! Neuron layer (DESIGN.md §4.5): the two stochastic neuron types the
//! paper contributes, plus analytic probability helpers.

pub mod sigmoid;
pub mod softmax_wta;

pub use sigmoid::SigmoidNeuron;
pub use softmax_wta::{WtaLayer, WtaOutcome};
