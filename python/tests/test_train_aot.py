"""Trainer + AOT exporter tests (kept light: tiny nets / few steps)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile import train as T


def test_adam_step_decreases_simple_loss():
    params = [jnp.array([[2.0]]), jnp.array([[2.0]])]
    opt = T.adam_init(params)

    def loss(ps):
        return sum(jnp.sum(w ** 2) for w in ps)

    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, opt = T.adam_step(params, grads, opt, lr=0.05)
    assert float(loss(params)) < l0 * 0.5


def test_short_training_learns(tmp_path):
    """5 epochs on 800 synthetic images must beat chance by a wide margin.

    (The full 25-epoch/12k run reaches ~97.5%; this is only a smoke test —
    the dataset's aggressive distortions make tiny-data accuracy modest.)
    """
    params, info, _, _ = T.train(n_train=800, n_test=200, epochs=5,
                                 batch=64, verbose=False)
    assert info["ideal_test_accuracy"] > 0.35  # chance = 0.1
    # weight save/load roundtrip
    T.save_weights(params, str(tmp_path / "w"), info)
    params2, meta = T.load_weights(str(tmp_path / "w"))
    for a, b in zip(params, params2):
        assert jnp.allclose(a, b)
    assert meta["layers"] == list(M.LAYERS)


def test_weights_respect_clip():
    params, _, _, _ = T.train(n_train=300, n_test=100, epochs=1,
                              batch=64, verbose=False)
    for w in params:
        assert float(jnp.max(jnp.abs(w))) <= 4.0 + 1e-6


def test_export_smoke_hlo(tmp_path):
    path = aot.export_smoke(str(tmp_path))
    text = open(path).read()
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_export_trial_small_params(tmp_path):
    """Export a trial HLO for a tiny net and check the entry signature."""
    params = M.init_params(jax.random.PRNGKey(0), (12, 8, 6, 4))
    # monkeypatch-free: call the underlying pieces with a tiny batch
    frozen = [jnp.asarray(w) for w in params]

    def fn(x, seed, sigma_z, theta):
        return (M.raca_trial_from_seed(frozen, x, seed, sigma_z, theta),)

    specs = (
        jax.ShapeDtypeStruct((2, 12), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.uint32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule")
    assert "(f32[2,12]{1,0}, u32[], f32[], f32[])->(s32[2]{0})" in text


def test_sha256_stable(tmp_path):
    p = tmp_path / "x.bin"
    p.write_bytes(b"hello")
    assert aot.sha256(str(p)) == (
        "2cf24dba5fb0a30e26e83b2ac5b9e29e1b161e5c1fa7425e73043362938b9824")
