"""Synthetic dataset generator tests (data.py)."""

import numpy as np
import pytest

from compile import data as D


def test_deterministic():
    a_img, a_lbl = D.generate(50, seed=3)
    b_img, b_lbl = D.generate(50, seed=3)
    assert np.array_equal(a_img, b_img)
    assert np.array_equal(a_lbl, b_lbl)


def test_seed_changes_data():
    a_img, _ = D.generate(50, seed=3)
    b_img, _ = D.generate(50, seed=4)
    assert not np.array_equal(a_img, b_img)


def test_shapes_and_range():
    img, lbl = D.generate(40, seed=0)
    assert img.shape == (40, 784) and img.dtype == np.float32
    assert lbl.shape == (40,) and lbl.dtype == np.int32
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0
    assert img.max() > 0.5  # strokes actually rendered


def test_class_balance():
    _, lbl = D.generate(100, seed=1)
    counts = np.bincount(lbl, minlength=10)
    assert np.array_equal(counts, np.full(10, 10))


def test_digits_are_distinguishable():
    """Mean intra-class distance should be well below inter-class distance."""
    img, lbl = D.generate(200, seed=5)
    mus = np.stack([img[lbl == d].mean(axis=0) for d in range(10)])
    intra = np.mean([
        np.linalg.norm(img[lbl == d] - mus[d], axis=1).mean() for d in range(10)
    ])
    dists = [np.linalg.norm(mus[i] - mus[j]) for i in range(10) for j in range(i + 1, 10)]
    assert min(dists) > 0.5 * intra / np.sqrt(200 / 10)


def test_bin_roundtrip(tmp_path):
    img, lbl = D.generate(30, seed=9)
    D.save_bin(str(tmp_path / "t"), img, lbl)
    img2, lbl2 = D.load_bin(str(tmp_path / "t"))
    assert np.array_equal(img, img2) and np.array_equal(lbl, lbl2)


def test_all_templates_render():
    rng = np.random.default_rng(0)
    for d in range(10):
        im = D.render_digit(d, rng)
        assert im.shape == (28, 28)
        assert im.sum() > 5.0, f"digit {d} rendered empty"
