"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/seeds/σ so the kernels are exercised across
padding boundaries (non-multiples of the 128 tile), degenerate sizes and
extreme noise scales.  Binary outputs must match the oracle *exactly*;
the MAC must match to f32 tolerance.
"""

import hypothesis as hp
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import pytest

from compile.kernels import crossbar as xk
from compile.kernels import ref as kref
from compile.kernels import wta as wk

hp.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hp.HealthCheck.too_slow, hp.HealthCheck.data_too_large])
hp.settings.load_profile("ci")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# crossbar MAC
# ---------------------------------------------------------------------------

@hp.given(
    b=st.integers(1, 17),
    n_in=st.integers(1, 300),
    n_out=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_mac_matches_ref(b, n_in, n_out, seed):
    x = rand(seed, b, n_in)
    w = rand(seed + 1, n_in, n_out)
    got = xk.crossbar_mac(x, w)
    want = kref.crossbar_mac_ref(x, w)
    assert got.shape == want.shape
    assert jnp.allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("b,n_in,n_out", [
    (1, 1, 1),            # degenerate
    (1, 785, 500),        # layer-1 shape
    (32, 501, 300),       # layer-2 shape, batched
    (3, 301, 10),         # output layer shape
    (128, 128, 128),      # exact tile multiples
    (129, 129, 129),      # one past the tile boundary
])
def test_mac_paper_shapes(b, n_in, n_out):
    x = rand(7, b, n_in)
    w = rand(8, n_in, n_out)
    assert jnp.allclose(
        xk.crossbar_mac(x, w), kref.crossbar_mac_ref(x, w), atol=2e-4, rtol=2e-4)


def test_mac_block_sizes_equivalent():
    """Different VMEM tilings must not change the numerics."""
    x = rand(1, 9, 200)
    w = rand(2, 200, 70)
    base = kref.crossbar_mac_ref(x, w)
    for bk in (32, 64, 128, 256):
        got = xk.crossbar_mac(x, w, bk=bk)
        assert jnp.allclose(got, base, atol=1e-4), f"bk={bk}"


# ---------------------------------------------------------------------------
# fused stochastic sigmoid layer
# ---------------------------------------------------------------------------

@hp.given(
    b=st.integers(1, 9),
    n_in=st.integers(1, 200),
    n_out=st.integers(1, 150),
    sigma=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sigmoid_layer_matches_ref(b, n_in, n_out, sigma, seed):
    x = jax.nn.relu(rand(seed, b, n_in))  # non-negative activations
    w = rand(seed + 1, n_in, n_out)
    n = sigma * rand(seed + 2, b, n_out)
    got = xk.crossbar_layer(x, w, n, binarize=True)
    want = kref.stoch_sigmoid_layer_ref(x, w, n / sigma, sigma)
    assert jnp.array_equal(got, want)
    assert set(jnp.unique(got).tolist()) <= {0.0, 1.0}


def test_sigmoid_layer_zero_noise_is_step():
    """σ→0 degenerates to a hard threshold at Z=0."""
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.array([[1.0, -1.0]] * 4, jnp.float32)
    n = jnp.zeros((2, 2), jnp.float32)
    out = xk.crossbar_layer(x, w, n, binarize=True)
    assert jnp.array_equal(out, jnp.array([[1.0, 0.0], [1.0, 0.0]]))


def test_activation_probability_is_sigmoid():
    """Empirical firing rate ≈ logistic(z) at the calibrated σ_z = 1.702.

    This is the paper's core claim (Eq. 13) — checked statistically at the
    kernel level with 20k samples per z-point.
    """
    from compile import physics

    sigma_z = physics.noise_std_normalized(1.0)
    zs = jnp.array([-4.0, -2.0, -1.0, 0.0, 1.0, 2.0, 4.0])
    k = 20000
    x = jnp.ones((k, 1), jnp.float32)
    for z in zs:
        w = jnp.full((1, 1), z, jnp.float32)
        noise = sigma_z * rand(int(abs(float(z)) * 1000) + 3, k, 1)
        fires = xk.crossbar_layer(x, w, noise, binarize=True)
        p_hat = float(fires.mean())
        p_log = float(jax.nn.sigmoid(z))
        # probit vs logit maximum gap is ~0.0095 at the matched constant;
        # add 3σ binomial sampling margin.
        margin = 0.0095 + 3.0 * (p_log * (1 - p_log) / k) ** 0.5 + 0.01
        assert abs(p_hat - p_log) < margin, (float(z), p_hat, p_log)


# ---------------------------------------------------------------------------
# WTA first-crossing kernel
# ---------------------------------------------------------------------------

@hp.given(
    b=st.integers(1, 8),
    c=st.integers(2, 12),
    t=st.integers(1, 80),
    theta=st.floats(-1.0, 6.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_wta_matches_ref(b, c, t, theta, seed):
    z = rand(seed, b, c)
    noise = 1.702 * rand(seed + 1, b, t, c)
    got = wk.wta_first_crossing(z - theta, noise)
    want = kref.wta_first_crossing_ref(z, noise / 1.702, theta, 1.702)
    assert jnp.array_equal(got, want)


def test_wta_abstains_when_unreachable():
    z = jnp.full((4, 10), -100.0, jnp.float32)
    noise = rand(5, 4, 16, 10)
    out = wk.wta_first_crossing(z - 3.0, noise)
    assert jnp.array_equal(out, -jnp.ones(4, jnp.int32))


def test_wta_picks_dominant_neuron():
    """With one neuron far above threshold it must always win."""
    z = jnp.zeros((6, 10), jnp.float32).at[:, 7].set(50.0)
    noise = 1.702 * rand(11, 6, 32, 10)
    out = wk.wta_first_crossing(z - 3.0, noise)
    assert jnp.array_equal(out, jnp.full(6, 7, jnp.int32))


def test_wta_single_winner_per_trial():
    """The kernel returns exactly one index — WTA's defining property."""
    z = rand(13, 5, 10)
    noise = 1.702 * rand(14, 5, 64, 10)
    out = wk.wta_first_crossing(z - 0.5, noise)
    assert out.shape == (5,)
    assert bool(jnp.all((out >= -1) & (out < 10)))
