"""Physics/calibration unit tests (paper Eq. 1, 4–7, 13 — DESIGN.md §6)."""

import math

import pytest

from compile import physics as P


def test_weight_mapping_endpoints():
    """Eq. 4/5/7: W_min → G_min, W_max → G_max, W=0 → Gref."""
    assert P.weight_to_conductance(-P.W_CLIP) == pytest.approx(P.G_MIN)
    assert P.weight_to_conductance(P.W_CLIP) == pytest.approx(P.G_MAX)
    assert P.weight_to_conductance(0.0) == pytest.approx(P.g_ref())


def test_weight_mapping_is_affine():
    g1 = P.weight_to_conductance(1.0)
    g2 = P.weight_to_conductance(2.0)
    g3 = P.weight_to_conductance(3.0)
    assert (g2 - g1) == pytest.approx(g3 - g2)
    assert (g2 - g1) == pytest.approx(P.g0())


def test_conductances_stay_physical():
    """Any clipped weight maps inside [G_MIN, G_MAX] — programmable range."""
    for w in [-4.0, -1.5, 0.0, 0.3, 4.0]:
        g = P.weight_to_conductance(w)
        assert P.G_MIN - 1e-12 <= g <= P.G_MAX + 1e-12


def test_nyquist_noise_scales_sqrt():
    """Eq. 1: σ ∝ sqrt(Δf) and ∝ sqrt(N_col)."""
    s1 = P.column_noise_sigma(100, 1e9)
    s2 = P.column_noise_sigma(100, 4e9)
    s3 = P.column_noise_sigma(400, 1e9)
    assert s2 == pytest.approx(2 * s1, rel=1e-9)
    assert s3 == pytest.approx(2 * s1, rel=1e-9)


def test_calibration_fixes_kappa():
    """calibrate_vr must place κ exactly at snr_scale/1.702 (Eq. 13)."""
    for n_col in (98, 785, 1570):
        for df in (1e8, 1e9, 1e10):
            for s in (0.25, 1.0, 4.0):
                vr = P.calibrate_vr(n_col, df, s)
                k = P.kappa(vr, n_col, df)
                assert k == pytest.approx(s / P.SIGMOID_PROBIT, rel=1e-9)


def test_normalized_noise_std():
    assert P.noise_std_normalized(1.0) == pytest.approx(1.702)
    assert P.noise_std_normalized(2.0) == pytest.approx(0.851)


def test_tia_threshold_roundtrip():
    """theta_norm_for_vth0 inverts tia_resistance."""
    r = P.tia_resistance(0.05, n_col=301, theta_norm=3.0)
    assert P.theta_norm_for_vth0(0.05, r, n_col=301) == pytest.approx(3.0)
    assert P.theta_norm_for_vth0(0.0, r, n_col=301) == pytest.approx(0.0)


def test_probit_logistic_approx_quality():
    """max |sigmoid(z) − Φ(z/1.702)| < 0.0095 (the classic bound)."""
    from math import erf
    worst = max(
        abs(1 / (1 + math.exp(-z)) - 0.5 * (1 + erf(z / 1.702 / math.sqrt(2))))
        for z in [i / 100 for i in range(-600, 601)]
    )
    assert worst < 0.0095


def test_design_point_serialization():
    d = P.DesignPoint().to_dict()
    for key in ("layers", "g0", "g_ref", "sigma_z", "vr_per_layer", "r_tia"):
        assert key in d
    assert len(d["vr_per_layer"]) == 3
    assert d["sigma_z"] == pytest.approx(1.702)
    # Read voltage should be small (paper: well below normal read voltage).
    assert all(0 < v < 0.5 for v in d["vr_per_layer"])
