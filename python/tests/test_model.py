"""L2 model tests: ideal forward, stochastic trials, voting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import physics

SMALL = (12, 8, 6, 4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), SMALL)


def test_init_shapes(params):
    assert [tuple(w.shape) for w in params] == [(13, 8), (9, 6), (7, 4)]


def test_ideal_forward_is_distribution(params):
    x = jax.random.uniform(jax.random.PRNGKey(1), (5, 12))
    p = M.ideal_forward(params, x)
    assert p.shape == (5, 4)
    assert jnp.allclose(p.sum(axis=1), 1.0, atol=1e-5)
    assert bool(jnp.all(p >= 0))


def test_clip_params_bounds(params):
    big = [w * 100 for w in params]
    for w in M.clip_params(big):
        assert float(jnp.max(jnp.abs(w))) <= physics.W_CLIP


def test_trial_kernel_vs_ref_paths(params):
    """The pallas-kernel trial and the pure-jnp trial must agree exactly
    (same PRNG stream, same tie-breaking)."""
    x = jax.random.uniform(jax.random.PRNGKey(2), (6, 12))
    key = jax.random.PRNGKey(3)
    sz = jnp.float32(1.702)
    th = jnp.float32(1.0)
    a = M.raca_trial(params, x, key, sz, th, use_kernels=True)
    b = M.raca_trial(params, x, key, sz, th, use_kernels=False)
    assert jnp.array_equal(a, b)


def test_trial_from_seed_deterministic(params):
    x = jax.random.uniform(jax.random.PRNGKey(4), (3, 12))
    w1 = M.raca_trial_from_seed(params, x, jnp.uint32(9), jnp.float32(1.702),
                                jnp.float32(0.5))
    w2 = M.raca_trial_from_seed(params, x, jnp.uint32(9), jnp.float32(1.702),
                                jnp.float32(0.5))
    assert jnp.array_equal(w1, w2)
    w3 = M.raca_trial_from_seed(params, x, jnp.uint32(10), jnp.float32(1.702),
                                jnp.float32(0.5))
    assert w1.shape == w3.shape  # different seed may differ; shape stable


def test_trial_winners_in_range(params):
    x = jax.random.uniform(jax.random.PRNGKey(5), (8, 12))
    w = M.raca_trial_from_seed(params, x, jnp.uint32(1), jnp.float32(1.702),
                               jnp.float32(3.0))
    assert bool(jnp.all((w >= -1) & (w < 4)))


def test_vote_majority():
    winners = jnp.array([[0, 1, 2], [0, 1, 3], [1, 1, 3], [-1, 2, 3]], jnp.int32)
    v = M.vote(winners, num_classes=4)
    assert v.tolist() == [0, 1, 3]


def test_vote_ignores_abstentions():
    winners = jnp.array([[-1], [-1], [2]], jnp.int32)
    assert M.vote(winners, num_classes=4).tolist() == [2]


def test_wta_counts_converge_to_softmax(params):
    """Fig. 5(d) in miniature: WTA win frequencies ≈ softmax(z).

    Uses a θ in the logistic-tail regime and many decision trials on one
    fixed input.
    """
    x = jax.random.uniform(jax.random.PRNGKey(6), (1, 12))
    z = M.ideal_logits(params, x)[0]
    z = z - z.max()
    trials = 4000
    theta = jnp.float32(3.0)
    sz = jnp.float32(1.702)

    keys = jax.random.split(jax.random.PRNGKey(7), trials)
    xs = jnp.tile(x, (trials, 1))

    # Run the WTA layer directly on fixed logits (isolates the softmax
    # approximation from hidden-layer stochasticity).
    from compile.kernels import wta as wk
    noise = sz * jax.random.normal(jax.random.PRNGKey(8),
                                   (trials, physics.WTA_STEPS, 4))
    zb = jnp.tile(z[None, :], (trials, 1))
    winners = wk.wta_first_crossing(zb - theta, noise)
    winners = np.asarray(winners)
    counts = np.bincount(winners[winners >= 0], minlength=4).astype(float)
    p_hat = counts / counts.sum()
    p_soft = np.asarray(jax.nn.softmax(z))
    # Rank agreement and coarse value agreement.
    assert int(p_hat.argmax()) == int(p_soft.argmax())
    assert np.abs(p_hat - p_soft).max() < 0.12, (p_hat, p_soft)
