"""L1 Pallas kernels: differential crossbar MAC + fused stochastic comparator.

The crossbar tile is the paper's compute hot-spot: a 128×128 ReRAM array
performing `I_j − I_ref = Vr·G0·Σ_i x_i·W_ij` with the comparator sitting
directly on the bitline (no ADC).  The TPU mapping (DESIGN.md
§Hardware-Adaptation):

* one grid step = one 128(row)×128(col) crossbar tile resident in VMEM —
  the BlockSpec HBM↔VMEM schedule *is* the paper's N_col tile mapping;
* partial sums across row-tiles accumulate in the output block (revisited
  across the k grid axis), mirroring the analog partial-sum recombination;
* the stochastic comparator is fused into the matmul epilogue, so the
  pre-activation never materializes in HBM — the architectural analogue of
  "no ADC on the bitline".

All kernels are lowered with `interpret=True` (CPU PJRT cannot execute
Mosaic custom-calls); correctness vs `ref.py` is asserted by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Crossbar tile geometry (rows × cols) — the paper's array size.
TILE = 128


def _pad2(a: jax.Array, m: int, n: int) -> jax.Array:
    """Zero-pad a 2-D array up to (m, n)."""
    return jnp.pad(a, ((0, m - a.shape[0]), (0, n - a.shape[1])))


def _ceil_to(x: int, b: int) -> int:
    return (x + b - 1) // b * b


# ---------------------------------------------------------------------------
# Fused crossbar MAC (+ optional stochastic binarization epilogue)
# ---------------------------------------------------------------------------

def _mac_kernel(x_ref, w_ref, n_ref, o_ref, *, k_steps: int, binarize: bool):
    """One (bm × bn) output tile; grid axis 2 walks the k (row-tile) axis.

    The output block is revisited across k: initialize at k==0, accumulate
    partial sums (the analog column current of each row-tile), and at the
    final k step add the scaled comparator noise and threshold.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        if binarize:
            # Comparator on the bitline: fire = 1[Z + σ_z·n > 0] (Eq. 8/13).
            # n_ref already carries the σ_z scale (applied by the caller so
            # σ_z can stay a traced scalar — one HLO serves all SNR points).
            o_ref[...] = (o_ref[...] + n_ref[...] > 0.0).astype(jnp.float32)
        else:
            o_ref[...] += n_ref[...]


@functools.partial(
    jax.jit, static_argnames=("binarize", "bm", "bn", "bk", "interpret")
)
def crossbar_layer(
    x: jax.Array,
    w: jax.Array,
    noise_scaled: jax.Array,
    *,
    binarize: bool = True,
    bm: int = TILE,
    bn: int = TILE,
    bk: int = TILE,
    interpret: bool = True,
) -> jax.Array:
    """Crossbar layer: `Z = x @ w`, then `1[Z + noise > 0]` if `binarize`.

    x: (B, N_in) f32 — binary activations (or DAC'd input pixels, layer 0).
    w: (N_in, N_out) f32 — normalized weights (conductance mapping Eq. 4–7
       happens in the physical simulator; normalized units here).
    noise_scaled: (B, N_out) f32 — σ_z·N(0,1), pre-scaled by the caller.
    Returns (B, N_out) f32 (binary 0/1 if `binarize`, else Z + noise).
    """
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0]
    assert noise_scaled.shape == (x.shape[0], w.shape[1])
    m, k = x.shape
    n = w.shape[1]
    bm = min(bm, _ceil_to(m, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad2(x.astype(jnp.float32), mp, kp)
    wp = _pad2(w.astype(jnp.float32), kp, np_)
    # Padded noise must keep padded columns *off* (Z=0 + noise could fire);
    # use −inf so padded binary outputs are exactly 0 (sliced away anyway,
    # but keeps every intermediate well-defined).
    npad = jnp.full((mp, np_), -jnp.inf, dtype=jnp.float32)
    npad = npad.at[:m, :n].set(noise_scaled.astype(jnp.float32))
    if not binarize:
        npad = jnp.where(jnp.isinf(npad), 0.0, npad)

    k_steps = kp // bk
    grid = (mp // bm, np_ // bn, k_steps)
    out = pl.pallas_call(
        functools.partial(_mac_kernel, k_steps=k_steps, binarize=binarize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp, npad)
    return out[:m, :n]


def crossbar_mac(x: jax.Array, w: jax.Array, *, interpret: bool = True,
                 **block_kw) -> jax.Array:
    """Plain differential MAC (no comparator) — used by the WTA output layer."""
    zeros = jnp.zeros((x.shape[0], w.shape[1]), jnp.float32)
    return crossbar_layer(x, w, zeros, binarize=False, interpret=interpret,
                          **block_kw)
