"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here
written with plain `jax.numpy` ops only.  `python/tests/test_kernel.py`
asserts allclose/exact-equality between kernel and oracle across a
hypothesis-driven sweep of shapes, dtypes and parameters — this is the core
L1 correctness signal (the kernels lower into every HLO artifact).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def crossbar_mac_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differential crossbar MAC in normalized units: Z = x @ W.

    Physically: (I_j − I_ref)/(Vr·G0) = Σ_i x_i·W_ij  (paper Eq. 12).
    x: (B, N_in), w: (N_in, N_out) → (B, N_out), f32.
    """
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def stoch_binarize_ref(z: jax.Array, noise: jax.Array,
                       sigma_z: float) -> jax.Array:
    """Comparator with input-referred Gaussian noise (paper Eq. 8/13).

    fire = 1[z + σ_z·n > 0], n ~ N(0,1) supplied by the caller.
    Returns f32 zeros/ones (binary activations propagate as voltages).
    """
    return (z + sigma_z * noise > 0.0).astype(jnp.float32)


def stoch_sigmoid_layer_ref(x: jax.Array, w: jax.Array, noise: jax.Array,
                            sigma_z: float) -> jax.Array:
    """Fused crossbar MAC + stochastic binarization (one hidden layer)."""
    return stoch_binarize_ref(crossbar_mac_ref(x, w), noise, sigma_z)


def wta_first_crossing_ref(z: jax.Array, noise: jax.Array, theta: float,
                           sigma_z: float) -> jax.Array:
    """WTA decision oracle: index of the first neuron to cross V_th.

    z: (B, C) static output voltages (normalized), noise: (B, T, C) unit
    Gaussians — one per time step per neuron.  At step t neuron j crosses
    iff z_j + σ_z·n_tj > θ.  The winner is the earliest-crossing neuron;
    ties within a step break toward the largest instantaneous voltage;
    if nothing crosses in T steps the winner is −1 (abstain).

    Returns int32 (B,) winner indices.
    """
    zb = z[:, None, :] + sigma_z * noise           # (B, T, C) instantaneous
    crossed = zb > theta                           # (B, T, C) bool
    any_cross = jnp.any(crossed, axis=2)           # (B, T)
    t_first = jnp.argmax(any_cross, axis=1)        # (B,) first crossing step
    has_any = jnp.any(any_cross, axis=1)           # (B,)
    vb = jnp.take_along_axis(zb, t_first[:, None, None], axis=1)[:, 0, :]
    cb = jnp.take_along_axis(crossed, t_first[:, None, None], axis=1)[:, 0, :]
    masked = jnp.where(cb, vb, -jnp.inf)
    winner = jnp.argmax(masked, axis=1).astype(jnp.int32)
    return jnp.where(has_any, winner, jnp.int32(-1))


def ideal_sigmoid_ref(z: jax.Array) -> jax.Array:
    """Software logistic — the function the stochastic neuron emulates."""
    return jax.nn.sigmoid(z)


def ideal_softmax_ref(z: jax.Array) -> jax.Array:
    """Software SoftMax — the function the WTA neuron emulates (Eq. 14)."""
    return jax.nn.softmax(z, axis=-1)


def activation_probability_ref(z: jax.Array, sigma_z: float) -> jax.Array:
    """Analytic P(fire) = Φ(z/σ_z) (paper Eq. 13, normalized units)."""
    return 0.5 * (1.0 + jax.scipy.special.erf(z / (sigma_z * jnp.sqrt(2.0))))
