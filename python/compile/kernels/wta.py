"""L1 Pallas kernel: WTA binary stochastic SoftMax decision (paper §III-B).

One decision trial: the C output neurons' static voltages `z` (normalized,
threshold already subtracted by the caller) receive fresh comparator noise
every time step; the first neuron to cross wins and the adaptive threshold
is pulled to V_dd (so exactly one winner).  The kernel finds the winner of
each batch row in a single VMEM-resident pass over the (T, C) noise block —
the circuit's time evolution is data-parallel once the noise samples exist.

Grid: one program per batch row.  Matches `ref.wta_first_crossing_ref`
bit-exactly (same tie-breaking: earliest step, then largest voltage).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wta_kernel(z_ref, n_ref, o_ref):
    """z_ref: (1, C) rest voltages − θ; n_ref: (1, T, C) σ_z·N(0,1)."""
    z = z_ref[0, :]                     # (C,)
    n = n_ref[0, :, :]                  # (T, C)
    v = z[None, :] + n                  # instantaneous voltages − θ
    crossed = v > 0.0                   # (T, C)
    any_t = jnp.any(crossed, axis=1)    # (T,)
    t_first = jnp.argmax(any_t)         # first step with any crossing
    has_any = jnp.any(any_t)
    v_at = v[t_first, :]
    c_at = crossed[t_first, :]
    masked = jnp.where(c_at, v_at, -jnp.inf)
    winner = jnp.argmax(masked).astype(jnp.int32)
    o_ref[0] = jnp.where(has_any, winner, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def wta_first_crossing(z_minus_theta: jax.Array, noise_scaled: jax.Array,
                       *, interpret: bool = True) -> jax.Array:
    """Winner index per batch row, −1 if no neuron crosses within T steps.

    z_minus_theta: (B, C) f32 — static output voltage minus the rest
        threshold θ (caller folds θ and the σ_z scale, keeping both traced).
    noise_scaled: (B, T, C) f32 — σ_z·N(0,1) per step per neuron.
    Returns (B,) int32.
    """
    b, c = z_minus_theta.shape
    t = noise_scaled.shape[1]
    assert noise_scaled.shape == (b, t, c)
    return pl.pallas_call(
        _wta_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1, t, c), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(z_minus_theta.astype(jnp.float32), noise_scaled.astype(jnp.float32))
