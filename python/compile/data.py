"""Synthetic MNIST-like dataset (build-time; DESIGN.md §3 substitution).

No network access is available in this environment, so instead of the real
MNIST we procedurally render 28×28 grayscale digits from stroke templates
with random affine distortion, stroke-width jitter and pixel noise.  The
generator is deterministic given a seed and is mirrored 1:1 in
`rust/src/dataset/synth.rs` (same templates, same rasterizer) so the rust
side can regenerate smoke-test data without artifacts.

Exercised code path is identical to real MNIST: 784-dim float input in
[0,1], 10 classes, FCNN [784,500,300,10].
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Digit stroke templates: polylines in the unit square (x right, y down).
# Kept deliberately simple & unambiguous; distortions provide the variance.
# Mirrored in rust/src/dataset/synth.rs — keep in sync!
# ---------------------------------------------------------------------------

DIGIT_STROKES: dict[int, list[list[tuple[float, float]]]] = {
    0: [[(0.50, 0.08), (0.78, 0.22), (0.82, 0.50), (0.78, 0.78),
         (0.50, 0.92), (0.22, 0.78), (0.18, 0.50), (0.22, 0.22),
         (0.50, 0.08)]],
    1: [[(0.35, 0.25), (0.55, 0.10), (0.55, 0.90)],
        [(0.35, 0.90), (0.75, 0.90)]],
    2: [[(0.22, 0.30), (0.30, 0.12), (0.60, 0.08), (0.78, 0.25),
         (0.72, 0.48), (0.45, 0.65), (0.22, 0.88)],
        [(0.22, 0.88), (0.80, 0.88)]],
    3: [[(0.25, 0.15), (0.60, 0.10), (0.75, 0.28), (0.55, 0.46),
         (0.75, 0.68), (0.60, 0.90), (0.25, 0.85)]],
    4: [[(0.62, 0.90), (0.62, 0.10), (0.20, 0.62), (0.82, 0.62)]],
    5: [[(0.75, 0.12), (0.30, 0.12), (0.27, 0.45), (0.60, 0.42),
         (0.78, 0.62), (0.68, 0.86), (0.25, 0.88)]],
    6: [[(0.68, 0.10), (0.38, 0.30), (0.25, 0.60), (0.35, 0.85),
         (0.65, 0.88), (0.75, 0.65), (0.55, 0.50), (0.28, 0.58)]],
    7: [[(0.20, 0.12), (0.80, 0.12), (0.45, 0.90)],
        [(0.35, 0.52), (0.68, 0.52)]],
    8: [[(0.50, 0.10), (0.72, 0.22), (0.66, 0.44), (0.50, 0.50),
         (0.34, 0.44), (0.28, 0.22), (0.50, 0.10)],
        [(0.50, 0.50), (0.74, 0.62), (0.68, 0.86), (0.50, 0.92),
         (0.32, 0.86), (0.26, 0.62), (0.50, 0.50)]],
    9: [[(0.72, 0.42), (0.45, 0.50), (0.28, 0.35), (0.35, 0.12),
         (0.65, 0.10), (0.72, 0.42)],
        [(0.72, 0.42), (0.68, 0.70), (0.55, 0.90)]],
}

IMG = 28  # image side


def _rasterize(strokes: list[np.ndarray], width: float, soft: float) -> np.ndarray:
    """Anti-aliased polyline rasterizer: intensity from distance-to-segment.

    For every pixel, distance to the nearest point of any segment; intensity
    = clamp(1 − (d − width)/soft, 0, 1).  Vectorized over pixels.
    """
    ys, xs = np.mgrid[0:IMG, 0:IMG]
    px = (xs.astype(np.float64) + 0.5) / IMG
    py = (ys.astype(np.float64) + 0.5) / IMG
    dmin = np.full((IMG, IMG), 1e9)
    for poly in strokes:
        for k in range(len(poly) - 1):
            ax, ay = poly[k]
            bx, by = poly[k + 1]
            abx, aby = bx - ax, by - ay
            denom = abx * abx + aby * aby + 1e-12
            t = ((px - ax) * abx + (py - ay) * aby) / denom
            t = np.clip(t, 0.0, 1.0)
            cx, cy = ax + t * abx, ay + t * aby
            d = np.sqrt((px - cx) ** 2 + (py - cy) ** 2)
            dmin = np.minimum(dmin, d)
    img = np.clip(1.0 - (dmin - width) / soft, 0.0, 1.0)
    return img.astype(np.float32)


def _affine(poly: np.ndarray, rot: float, sx: float, sy: float,
            shear: float, tx: float, ty: float) -> np.ndarray:
    """Affine-distort a polyline around the template centroid (0.5, 0.5)."""
    c, s = np.cos(rot), np.sin(rot)
    p = poly - 0.5
    x = p[:, 0] * sx + p[:, 1] * shear
    y = p[:, 1] * sy
    xr = c * x - s * y
    yr = s * x + c * y
    return np.stack([xr + 0.5 + tx, yr + 0.5 + ty], axis=1)


def render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    """One distorted 28×28 rendering of `digit`, values in [0, 1].

    Distortions are deliberately aggressive (rotation ±28°, scale 0.7–1.3,
    shear, jitter of every stroke vertex, occlusion patch, heavy pixel
    noise) so the task is MNIST-hard rather than trivially separable —
    the Fig. 6 accuracy-vs-trials curve needs headroom to be meaningful.
    """
    rot = rng.uniform(-0.5, 0.5)             # ±28°
    sx = rng.uniform(0.70, 1.30)
    sy = rng.uniform(0.70, 1.30)
    shear = rng.uniform(-0.3, 0.3)
    tx = rng.uniform(-0.12, 0.12)            # ±3.5 px
    ty = rng.uniform(-0.12, 0.12)
    width = rng.uniform(0.022, 0.065)        # stroke half-width
    soft = rng.uniform(0.020, 0.050)         # AA softness
    wobble = rng.uniform(0.0, 0.035)         # per-vertex jitter

    strokes = []
    for poly in DIGIT_STROKES[digit]:
        p = np.asarray(poly, dtype=np.float64)
        p = p + rng.normal(0.0, wobble, p.shape)
        strokes.append(_affine(p, rot, sx, sy, shear, tx, ty))
    img = _rasterize(strokes, width, soft)
    img *= rng.uniform(0.55, 1.0)                      # intensity jitter
    # Occlusion: zero a random small patch 30% of the time.
    if rng.uniform() < 0.3:
        ph, pw = rng.integers(3, 8), rng.integers(3, 8)
        py0 = rng.integers(0, IMG - ph)
        px0 = rng.integers(0, IMG - pw)
        img[py0:py0 + ph, px0:px0 + pw] = 0.0
    img += rng.normal(0.0, 0.10, img.shape).astype(np.float32)  # sensor noise
    return np.clip(img, 0.0, 1.0)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate `n` (image, label) pairs with balanced classes."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, IMG * IMG), dtype=np.float32)
    labels = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        d = i % 10
        images[i] = render_digit(d, rng).reshape(-1)
        labels[i] = d
    perm = rng.permutation(n)
    return images[perm], labels[perm]


def save_bin(path_prefix: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Flat little-endian binaries the rust loader reads (dataset/loader.rs)."""
    images.astype("<f4").tofile(path_prefix + ".img.bin")
    labels.astype("<i4").tofile(path_prefix + ".lbl.bin")


def load_bin(path_prefix: str) -> tuple[np.ndarray, np.ndarray]:
    images = np.fromfile(path_prefix + ".img.bin", dtype="<f4").reshape(-1, IMG * IMG)
    labels = np.fromfile(path_prefix + ".lbl.bin", dtype="<i4")
    return images, labels
