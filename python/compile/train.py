"""Build-time trainer for the RACA FCNN (SBNN-style, straight-through).

Trains the [784, 500, 300, 10] network on the synthetic MNIST dataset with
*stochastic binary* hidden activations in the forward pass (exactly what
the analog hardware executes: 1[z + σ_z·n > 0] with σ_z = 1.702) and a
straight-through sigmoid estimator in the backward pass — the standard SBNN
recipe the paper's "fully trained FCNN" refers to.  Weights are clipped to
the conductance-representable range [−W_CLIP, W_CLIP] after every step.

Pure JAX (no optax — offline environment); Adam implemented inline.
Run via `python -m compile.train` or (normally) through `compile.aot`.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as dataset
from compile import model as M
from compile import physics


def stochastic_forward_st(params, x, key, sigma_z):
    """Hidden layers with stochastic binarization + straight-through grad.

    h = sigmoid(z) + stop_grad(1[z + σ·n > 0] − sigmoid(z)): the forward
    value is the true binary sample, the gradient flows through sigmoid —
    so training sees the same activation statistics as the hardware.
    """
    h = x
    for w in params[:-1]:
        key, sub = jax.random.split(key)
        z = M.augment(h) @ w
        noise = jax.random.normal(sub, z.shape, jnp.float32)
        hard = (z + sigma_z * noise > 0.0).astype(jnp.float32)
        soft = jax.nn.sigmoid(z)
        h = soft + jax.lax.stop_gradient(hard - soft)
    return M.augment(h) @ params[-1]


def loss_fn(params, x, y, key, sigma_z):
    logits = stochastic_forward_st(params, x, key, sigma_z)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def adam_init(params):
    zeros = [jnp.zeros_like(w) for w in params]
    return {"m": zeros, "v": [jnp.zeros_like(w) for w in params], "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = [b1 * mi + (1 - b1) * g for mi, g in zip(state["m"], grads)]
    v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(state["v"], grads)]
    mh = [mi / (1 - b1**t) for mi in m]
    vh = [vi / (1 - b2**t) for vi in v]
    new = [
        jnp.clip(w - lr * mhi / (jnp.sqrt(vhi) + eps),
                 -physics.W_CLIP, physics.W_CLIP)
        for w, mhi, vhi in zip(params, mh, vh)
    ]
    return new, {"m": m, "v": v, "t": t}


@jax.jit
def eval_ideal(params, x, y):
    """Deterministic software accuracy (sigmoid/softmax argmax)."""
    probs = M.ideal_forward(params, x)
    return jnp.mean((jnp.argmax(probs, axis=1) == y).astype(jnp.float32))


def train(
    n_train: int = 12000,
    n_test: int = 2000,
    epochs: int = 25,
    batch: int = 128,
    lr: float = 1e-3,
    seed: int = 42,
    verbose: bool = True,
):
    """Train and return (params, info dict, train arrays, test arrays)."""
    xs, ys = dataset.generate(n_train, seed=seed)
    xt, yt = dataset.generate(n_test, seed=seed + 1000)
    key = jax.random.PRNGKey(seed)
    key, kinit = jax.random.split(key)
    params = M.init_params(kinit)
    opt = adam_init(params)
    sigma_z = jnp.float32(physics.noise_std_normalized(1.0))

    @jax.jit
    def step(params, opt, xb, yb, key):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb, key, sigma_z)
        params, opt = adam_step(params, grads, opt, lr=lr)
        return params, opt, loss

    n_batches = n_train // batch
    t0 = time.time()
    history = []
    for ep in range(epochs):
        key, kperm = jax.random.split(key)
        perm = np.asarray(jax.random.permutation(kperm, n_train))
        ep_loss = 0.0
        for b in range(n_batches):
            idx = perm[b * batch:(b + 1) * batch]
            key, kb = jax.random.split(key)
            params, opt, loss = step(params, opt, xs[idx], ys[idx], kb)
            ep_loss += float(loss)
        acc = float(eval_ideal(params, xt, yt))
        history.append({"epoch": ep, "loss": ep_loss / n_batches, "test_acc": acc})
        if verbose:
            print(f"epoch {ep:3d}  loss {ep_loss / n_batches:.4f}  "
                  f"ideal test acc {acc * 100:.2f}%  ({time.time() - t0:.0f}s)")
    info = {
        "ideal_test_accuracy": history[-1]["test_acc"],
        "epochs": epochs,
        "n_train": n_train,
        "n_test": n_test,
        "history": history,
    }
    return params, info, (xs, ys), (xt, yt)


def save_weights(params, path_prefix: str, info: dict) -> None:
    """Flat little-endian f32 + JSON metadata (rust nn/weights.rs format)."""
    flat = np.concatenate([np.asarray(w, dtype="<f4").reshape(-1) for w in params])
    flat.tofile(path_prefix + ".bin")
    meta = {
        "layers": list(M.LAYERS),
        "shapes": [list(w.shape) for w in params],
        "w_clip": physics.W_CLIP,
        "dtype": "f32le",
        **info,
    }
    with open(path_prefix + ".json", "w") as f:
        json.dump(meta, f, indent=1)


def load_weights(path_prefix: str):
    with open(path_prefix + ".json") as f:
        meta = json.load(f)
    flat = np.fromfile(path_prefix + ".bin", dtype="<f4")
    params, off = [], 0
    for shape in meta["shapes"]:
        n = int(np.prod(shape))
        params.append(jnp.asarray(flat[off:off + n].reshape(shape)))
        off += n
    return params, meta


if __name__ == "__main__":
    params, info, _, _ = train()
    save_weights(params, "/tmp/fcnn", info)
    print(f"final ideal accuracy: {info['ideal_test_accuracy'] * 100:.2f}%")
