"""L2: the RACA forward pass (JAX, build-time only).

Network: FCNN [784, 500, 300, 10] (paper §IV-C).  Hidden layers are binary
stochastic Sigmoid neurons (crossbar MAC + noisy comparator, L1 kernel);
the output layer is the WTA binary stochastic SoftMax neuron.  Bias is an
extra crossbar row driven by a constant-1 input (standard CiM practice), so
layer l has N_col = fan_in + 1 devices per column.

Everything works in *normalized z units* (see physics.py): the physical
current scale Vr·G0 divides out of the comparator decision, so the only
physical parameters that survive are σ_z = 1.702/snr_scale and the
normalized WTA threshold θ.  Both stay **traced scalars** so a single AOT
artifact serves every SNR / V_th0 sweep point of Fig. 6.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels import crossbar as xk
from compile.kernels import wta as wk
from compile.kernels import ref as kref
from compile import physics

LAYERS = (784, 500, 300, 10)

Params = Sequence[jax.Array]  # one augmented (fan_in+1, fan_out) matrix per layer


def augment(x: jax.Array) -> jax.Array:
    """Append the constant-1 bias row input: (B, N) → (B, N+1)."""
    return jnp.concatenate([x, jnp.ones((x.shape[0], 1), x.dtype)], axis=1)


def init_params(key: jax.Array, layers: Sequence[int] = LAYERS) -> list[jax.Array]:
    """Glorot-ish init of augmented weight matrices (bias row zero)."""
    params = []
    for i, (n_in, n_out) in enumerate(zip(layers[:-1], layers[1:])):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / (n_in + n_out))
        w = scale * jax.random.normal(sub, (n_in, n_out), jnp.float32)
        params.append(jnp.concatenate([w, jnp.zeros((1, n_out))], axis=0))
    return params


def clip_params(params: Params) -> list[jax.Array]:
    """Clip to the conductance-representable range [−W_CLIP, W_CLIP]."""
    return [jnp.clip(w, -physics.W_CLIP, physics.W_CLIP) for w in params]


# ---------------------------------------------------------------------------
# Ideal (software) forward — the functions the analog circuits emulate
# ---------------------------------------------------------------------------

def ideal_forward(params: Params, x: jax.Array) -> jax.Array:
    """Float sigmoid hidden layers + softmax output: (B, 784) → (B, 10)."""
    h = x
    for w in params[:-1]:
        h = kref.ideal_sigmoid_ref(augment(h) @ w)
    return kref.ideal_softmax_ref(augment(h) @ params[-1])


def ideal_logits(params: Params, x: jax.Array) -> jax.Array:
    h = x
    for w in params[:-1]:
        h = kref.ideal_sigmoid_ref(augment(h) @ w)
    return augment(h) @ params[-1]


# ---------------------------------------------------------------------------
# Stochastic (RACA hardware) forward — one decision trial
# ---------------------------------------------------------------------------

def raca_logits(params: Params, x: jax.Array, key: jax.Array,
                sigma_z: jax.Array, *, interpret: bool = True,
                use_kernels: bool = True) -> jax.Array:
    """Hidden layers through stochastic binary Sigmoid neurons → z_out.

    sigma_z: traced f32 scalar (1.702/snr_scale at the calibrated point).
    """
    h = x
    for li, w in enumerate(params[:-1]):
        key, sub = jax.random.split(key)
        ha = augment(h)
        noise = sigma_z * jax.random.normal(sub, (x.shape[0], w.shape[1]),
                                            jnp.float32)
        if use_kernels:
            h = xk.crossbar_layer(ha, w, noise, binarize=True,
                                  interpret=interpret)
        else:
            h = kref.stoch_sigmoid_layer_ref(ha, w, noise / sigma_z, sigma_z)
    ha = augment(h)
    if use_kernels:
        return xk.crossbar_mac(ha, params[-1], interpret=interpret)
    return kref.crossbar_mac_ref(ha, params[-1])


def raca_trial(params: Params, x: jax.Array, key: jax.Array,
               sigma_z: jax.Array, theta: jax.Array,
               *, wta_steps: int = physics.WTA_STEPS,
               interpret: bool = True, use_kernels: bool = True) -> jax.Array:
    """One full stochastic inference trial: (B, 784) → winner (B,) int32.

    theta: traced f32 scalar — normalized WTA rest threshold (V_th0 mapped
    through the TIA, physics.theta_norm_for_vth0).
    """
    key, kw = jax.random.split(key)
    z_out = raca_logits(params, x, key, sigma_z, interpret=interpret,
                        use_kernels=use_kernels)
    # The adaptive WTA threshold rests V_th0 above the *static mean* of the
    # output voltages (paper Fig. 3): subtract the per-row mean so θ is the
    # mean-relative rest offset — this is what the replica-column circuit
    # realizes and what makes the softmax-slope matching hold for any logit
    # offset (DESIGN.md §6).
    zc = z_out - jnp.mean(z_out, axis=1, keepdims=True)
    noise = sigma_z * jax.random.normal(
        kw, (x.shape[0], wta_steps, z_out.shape[1]), jnp.float32)
    if use_kernels:
        return wk.wta_first_crossing(zc - theta, noise, interpret=interpret)
    return kref.wta_first_crossing_ref(zc, noise / sigma_z, theta, sigma_z)


def raca_trial_from_seed(params: Params, x: jax.Array, seed: jax.Array,
                         sigma_z: jax.Array, theta: jax.Array,
                         *, wta_steps: int = physics.WTA_STEPS,
                         use_kernels: bool = True) -> jax.Array:
    """AOT entrypoint: scalar uint32 seed → winner indices (B,) int32.

    This is the function lowered to `artifacts/trial_fwd_b*.hlo.txt`; the
    rust coordinator calls it with a fresh seed per scheduled trial batch.
    """
    key = jax.random.PRNGKey(seed)
    return raca_trial(params, x, key, sigma_z, theta, wta_steps=wta_steps,
                      use_kernels=use_kernels)


# ---------------------------------------------------------------------------
# Voting (reference implementation of the coordinator's counter logic)
# ---------------------------------------------------------------------------

def vote(winners: jax.Array, num_classes: int = 10) -> jax.Array:
    """Majority vote over trials: winners (K, B) int32 → (B,) int32.

    Abstentions (−1) are ignored; ties break toward the lower class index
    (same rule as rust `coordinator::votes`).
    """
    counts = jnp.stack(
        [(winners == c).sum(axis=0) for c in range(num_classes)], axis=1)
    return jnp.argmax(counts, axis=1).astype(jnp.int32)
