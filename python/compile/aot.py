"""AOT build driver: dataset → training → HLO-text artifacts + manifest.

Run as `python -m compile.aot --out ../artifacts` (see Makefile `artifacts`
target).  Python never runs again after this: the rust coordinator loads
`artifacts/*.hlo.txt` through the PJRT C API and is self-contained.

HLO **text** is the interchange format (NOT `.serialize()`): jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts produced (DESIGN.md §7):
  data/{train,test}.{img,lbl}.bin   synthetic MNIST
  weights/fcnn.{bin,json}           trained [784,500,300,10] parameters
  smoke.hlo.txt                     tiny matmul+2 (runtime unit tests)
  ideal_fwd_b{1,256}.hlo.txt        float reference forward
  trial_fwd_b{1,32,256}.hlo.txt     one stochastic trial (seed,σ_z,θ params)
  manifest.json                     shapes, hashes, calibration record
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import data as dataset
from compile import model as M
from compile import physics
from compile import train as T

TRIAL_BATCHES = (1, 32, 256)
IDEAL_BATCHES = (1, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def export_smoke(out_dir: str) -> str:
    """fn(x, y) = (x@y + 2,) over f32[2,2] — fast-compiling runtime smoke."""

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    path = os.path.join(out_dir, "smoke.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def weight_specs(params):
    return tuple(
        jax.ShapeDtypeStruct(tuple(w.shape), jnp.float32) for w in params)


def export_ideal(params, out_dir: str, batch: int) -> str:
    """(x[B,784], w1, w2, w3) → (probs[B,10],).

    Weights are **runtime parameters**, not baked constants: the HLO text
    printer elides tensors above a size threshold (`constant({...})`), so
    constants would not survive the text round-trip.  The rust runtime
    uploads `weights/fcnn.bin` once as device-resident PJRT buffers and
    reuses them across every call (`execute_b`).
    """

    def fn(x, *ws):
        return (M.ideal_forward(list(ws), x),)

    specs = (jax.ShapeDtypeStruct((batch, 784), jnp.float32),) + weight_specs(params)
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    path = os.path.join(out_dir, f"ideal_fwd_b{batch}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def export_trial(params, out_dir: str, batch: int) -> str:
    """(x[B,784], w1, w2, w3, seed u32, σ_z f32, θ f32) → (winner i32[B],).

    σ_z and θ are runtime scalars so ONE artifact serves every SNR/V_th0
    point of Fig. 6 — the rust coordinator sweeps them without recompiling.
    """

    def fn(x, w1, w2, w3, seed, sigma_z, theta):
        return (M.raca_trial_from_seed((w1, w2, w3), x, seed, sigma_z, theta),)

    specs = (
        (jax.ShapeDtypeStruct((batch, 784), jnp.float32),)
        + weight_specs(params)
        + (
            jax.ShapeDtypeStruct((), jnp.uint32),
            jax.ShapeDtypeStruct((), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
    )
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    path = os.path.join(out_dir, f"trial_fwd_b{batch}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=25)
    ap.add_argument("--n-train", type=int, default=12000)
    ap.add_argument("--n-test", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--force", action="store_true",
                    help="retrain / regenerate even if outputs exist")
    args = ap.parse_args()

    out = os.path.abspath(args.out)
    os.makedirs(os.path.join(out, "data"), exist_ok=True)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)
    t0 = time.time()

    # -- dataset ------------------------------------------------------------
    train_prefix = os.path.join(out, "data", "train")
    test_prefix = os.path.join(out, "data", "test")
    if args.force or not os.path.exists(train_prefix + ".img.bin"):
        print(f"[aot] generating synthetic MNIST "
              f"({args.n_train} train / {args.n_test} test)…")
        xs, ys = dataset.generate(args.n_train, seed=args.seed)
        xt, yt = dataset.generate(args.n_test, seed=args.seed + 1000)
        dataset.save_bin(train_prefix, xs, ys)
        dataset.save_bin(test_prefix, xt, yt)
    else:
        print("[aot] dataset exists, skipping")
        xt, yt = dataset.load_bin(test_prefix)

    # -- training -----------------------------------------------------------
    wprefix = os.path.join(out, "weights", "fcnn")
    if args.force or not os.path.exists(wprefix + ".bin"):
        print("[aot] training FCNN [784,500,300,10] (SBNN straight-through)…")
        params, info, _, _ = T.train(
            n_train=args.n_train, n_test=args.n_test,
            epochs=args.epochs, seed=args.seed)
        T.save_weights(params, wprefix, info)
    else:
        print("[aot] weights exist, skipping training")
        params, meta = T.load_weights(wprefix)
        info = {"ideal_test_accuracy": meta.get("ideal_test_accuracy", -1.0)}

    # -- HLO artifacts --------------------------------------------------------
    paths = [export_smoke(out)]
    print(f"[aot] wrote {paths[-1]}")
    for b in IDEAL_BATCHES:
        paths.append(export_ideal(params, out, b))
        print(f"[aot] wrote {paths[-1]} ({time.time() - t0:.0f}s)")
    for b in TRIAL_BATCHES:
        paths.append(export_trial(params, out, b))
        print(f"[aot] wrote {paths[-1]} ({time.time() - t0:.0f}s)")

    # -- manifest -------------------------------------------------------------
    dp = physics.DesignPoint()
    manifest = {
        "design_point": dp.to_dict(),
        "theta_norm_vth0_005": physics.THETA_NORM_DEFAULT,
        "theta_norm_vth0_0": 0.0,
        "trial_batches": list(TRIAL_BATCHES),
        "ideal_batches": list(IDEAL_BATCHES),
        "ideal_test_accuracy": info["ideal_test_accuracy"],
        "files": {
            os.path.relpath(p, out): {"sha256": sha256(p),
                                      "bytes": os.path.getsize(p)}
            for p in paths + [
                train_prefix + ".img.bin", train_prefix + ".lbl.bin",
                test_prefix + ".img.bin", test_prefix + ".lbl.bin",
                wprefix + ".bin", wprefix + ".json",
            ]
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest written; total {time.time() - t0:.0f}s; "
          f"ideal accuracy {info['ideal_test_accuracy'] * 100:.2f}%")


if __name__ == "__main__":
    main()
